//! Minimal command-line argument parser (clap substitute).
//!
//! Supports the patterns the `npusim` binary and examples need:
//! `prog <subcommand> [positional...] [--flag] [--key value] [--key=value]`.

use std::collections::BTreeMap;

/// Uniform unknown-variant error shared by [`CliEnum`] and ad-hoc flag
/// parsers whose variants carry payloads (e.g. fault-event kinds).
pub fn unknown_variant(what: &str, got: &str, variants: &str) -> anyhow::Error {
    anyhow::anyhow!("unknown {what} {got:?} ({variants})")
}

/// A small closed CLI enum: one table of `(canonical name, aliases, value)`
/// per variant drives flag parsing, `--help` variant lists, and error
/// messages uniformly, instead of each enum hand-rolling a stringly-typed
/// `parse`/`name` pair.
pub trait CliEnum: Sized + Copy + PartialEq + 'static {
    /// What the flag selects, for error messages (e.g. `"router"`).
    const WHAT: &'static str;
    /// One row per variant: canonical name, accepted aliases, value.
    const TABLE: &'static [(&'static str, &'static [&'static str], Self)];

    /// `a|b|c` list of canonical names (help text and error messages).
    fn variants() -> String {
        Self::TABLE
            .iter()
            .map(|(n, _, _)| *n)
            .collect::<Vec<_>>()
            .join("|")
    }

    /// Parse a flag value; canonical names and aliases both accepted.
    fn parse_cli(s: &str) -> anyhow::Result<Self> {
        for (name, aliases, v) in Self::TABLE {
            if *name == s || aliases.contains(&s) {
                return Ok(*v);
            }
        }
        Err(unknown_variant(Self::WHAT, s, &Self::variants()))
    }

    /// Canonical name of this variant.
    fn cli_name(self) -> &'static str {
        Self::TABLE
            .iter()
            .find(|(_, _, v)| *v == self)
            .map(|(n, _, _)| *n)
            .expect("every variant has a TABLE row")
    }
}

/// Parsed arguments: a subcommand, positional args, and `--key value` opts.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping `argv\[0\]`).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an explicit iterator (used by tests).
    pub fn parse(args: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.opts.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// `--key value` lookup.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// `--key value` with a default.
    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    /// Typed option lookup (parses with `FromStr`).
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>> {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("invalid value for --{key}: {s:?}")),
        }
    }

    /// Typed option with default.
    pub fn opt_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T> {
        Ok(self.opt_parse(key)?.unwrap_or(default))
    }

    /// Bare `--flag` presence.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.opts.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = sv(&["experiment", "fig9"]);
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig9"]);
    }

    #[test]
    fn key_value_both_syntaxes() {
        let a = sv(&["serve", "--model=qwen3_4b", "--tp", "4"]);
        assert_eq!(a.opt("model"), Some("qwen3_4b"));
        assert_eq!(a.opt("tp"), Some("4"));
    }

    #[test]
    fn flags_without_values() {
        let a = sv(&["sweep", "--fast", "--csv"]);
        assert!(a.flag("fast"));
        assert!(a.flag("csv"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn typed_parse() {
        let a = sv(&["x", "--tp", "16", "--ratio", "2.5"]);
        assert_eq!(a.opt_parse::<usize>("tp").unwrap(), Some(16));
        assert_eq!(a.opt_parse_or::<f64>("ratio", 1.0).unwrap(), 2.5);
        assert_eq!(a.opt_parse_or::<u64>("missing", 7).unwrap(), 7);
    }

    #[test]
    fn typed_parse_error() {
        let a = sv(&["x", "--tp", "nope"]);
        assert!(a.opt_parse::<usize>("tp").is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = sv(&["x", "--fast", "--tp", "4"]);
        assert!(a.flag("fast"));
        assert_eq!(a.opt("tp"), Some("4"));
    }

    #[derive(Debug, Clone, Copy, PartialEq)]
    enum Fruit {
        Apple,
        Pear,
    }

    impl CliEnum for Fruit {
        const WHAT: &'static str = "fruit";
        const TABLE: &'static [(&'static str, &'static [&'static str], Fruit)] = &[
            ("apple", &["a"], Fruit::Apple),
            ("pear", &[], Fruit::Pear),
        ];
    }

    #[test]
    fn cli_enum_parses_names_and_aliases() {
        assert_eq!(Fruit::parse_cli("apple").unwrap(), Fruit::Apple);
        assert_eq!(Fruit::parse_cli("a").unwrap(), Fruit::Apple);
        assert_eq!(Fruit::parse_cli("pear").unwrap(), Fruit::Pear);
        assert_eq!(Fruit::Pear.cli_name(), "pear");
        assert_eq!(Fruit::variants(), "apple|pear");
    }

    #[test]
    fn cli_enum_error_lists_variants() {
        let err = Fruit::parse_cli("mango").unwrap_err().to_string();
        assert_eq!(err, "unknown fruit \"mango\" (apple|pear)");
    }
}
