//! Minimal TOML-subset parser (the `toml` crate is unavailable offline).
//!
//! Supports what the config files in `configs/` use:
//! - `[table]` and `[table.subtable]` headers
//! - `key = value` with string / integer / float / boolean / array values
//! - `#` comments, blank lines
//!
//! Not supported (and not needed): inline tables, arrays-of-tables,
//! multi-line strings, datetimes.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Floats accept integer literals too (`sram_mb = 32`).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with 1-based line number.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "minitoml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document: dotted-path keys (`table.key`) to values.
#[derive(Debug, Clone, Default)]
pub struct Document {
    entries: BTreeMap<String, Value>,
}

impl Document {
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut doc = Document::default();
        let mut prefix = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| ParseError {
                    line: lineno,
                    msg: "unterminated table header".into(),
                })?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(ParseError {
                        line: lineno,
                        msg: "empty table name".into(),
                    });
                }
                prefix = name.to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| ParseError {
                line: lineno,
                msg: format!("expected `key = value`, got {line:?}"),
            })?;
            let key = key.trim();
            let value = parse_value(value.trim()).map_err(|msg| ParseError { line: lineno, msg })?;
            let full = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{prefix}.{key}")
            };
            doc.entries.insert(full, value);
        }
        Ok(doc)
    }

    /// Look up a value by dotted path.
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }
    pub fn get_int(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_int)
    }
    pub fn get_float(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_float)
    }
    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }

    /// All keys under a table prefix (`prefix.` stripped).
    pub fn keys_under(&self, prefix: &str) -> Vec<&str> {
        let pat = format!("{prefix}.");
        self.entries
            .keys()
            .filter_map(|k| k.strip_prefix(&pat))
            .collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a string literal must not start a comment.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s:?}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array: {s:?}"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim())?);
        }
        return Ok(Value::Array(items));
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

/// Split an array body on commas that are not nested in strings/brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, ch) in s.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let doc = Document::parse(
            r#"
name = "qwen3_4b"   # a comment
layers = 36
rope_theta = 1000000.0
moe = false
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("qwen3_4b"));
        assert_eq!(doc.get_int("layers"), Some(36));
        assert_eq!(doc.get_float("rope_theta"), Some(1_000_000.0));
        assert_eq!(doc.get_bool("moe"), Some(false));
    }

    #[test]
    fn tables_prefix_keys() {
        let doc = Document::parse(
            "[chip]\ncores = 64\n[chip.noc]\nbw_gbps = 128\n",
        )
        .unwrap();
        assert_eq!(doc.get_int("chip.cores"), Some(64));
        assert_eq!(doc.get_int("chip.noc.bw_gbps"), Some(128));
    }

    #[test]
    fn arrays() {
        let doc = Document::parse("dims = [32, 64, 128]\nnames = [\"a\", \"b\"]\n").unwrap();
        let dims = doc.get("dims").unwrap().as_array().unwrap();
        assert_eq!(dims.len(), 3);
        assert_eq!(dims[2].as_int(), Some(128));
        let names = doc.get("names").unwrap().as_array().unwrap();
        assert_eq!(names[1].as_str(), Some("b"));
    }

    #[test]
    fn int_accepted_as_float() {
        let doc = Document::parse("x = 32\n").unwrap();
        assert_eq!(doc.get_float("x"), Some(32.0));
    }

    #[test]
    fn underscores_in_numbers() {
        let doc = Document::parse("big = 1_000_000\n").unwrap();
        assert_eq!(doc.get_int("big"), Some(1_000_000));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = Document::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc.get_str("s"), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Document::parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn keys_under_lists_table_members() {
        let doc = Document::parse("[m]\na = 1\nb = 2\n[other]\nc = 3\n").unwrap();
        let mut keys = doc.keys_under("m");
        keys.sort();
        assert_eq!(keys, vec!["a", "b"]);
    }
}
