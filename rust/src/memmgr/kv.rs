//! The combined multi-grained KV cache (Fig. 5): fine-grained SRAM blocks
//! with spill into coarse-grained per-request HBM ring buffers.
//!
//! One `KvCache` instance manages the KV memory of one worker group (all
//! cores of a TP group share the same residency statistics since the KV is
//! head-sharded uniformly across them).

use super::blocks::{BlockAllocator, Chain};
use super::ring::{RingAlloc, RingBuffer};
use std::collections::HashMap;

/// Where a request's KV bytes currently live. The attention operator
/// charges HBM streaming time for the `hbm_bytes` portion.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct KvResidency {
    pub sram_bytes: u64,
    pub hbm_bytes: u64,
}

impl KvResidency {
    pub fn total(&self) -> u64 {
        self.sram_bytes + self.hbm_bytes
    }
}

#[derive(Debug)]
struct Entry {
    chain: Chain,
    hbm: Option<RingAlloc>,
    res: KvResidency,
}

/// Outcome of appending tokens: how many new bytes landed where (the
/// `hbm_bytes` part is what the executor charges as spill writeback).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Appended {
    pub sram_bytes: u64,
    pub hbm_bytes: u64,
}

/// Multi-grained KV cache for one worker group.
#[derive(Debug)]
pub struct KvCache {
    sram: BlockAllocator,
    hbm: RingBuffer,
    /// Bytes of K+V per token (for this group's layer/head shard).
    bytes_per_token: u64,
    /// HBM buffer size reserved per admitted request (max token length).
    max_request_bytes: u64,
    entries: HashMap<u64, Entry>,
    /// Bytes that could not be stored anywhere (admission bug if > 0).
    overflow_bytes: u64,
}

impl KvCache {
    /// * `sram_kv_bytes`: the planner's SRAM KV budget for this group.
    /// * `block_tokens`: tokens per SRAM block (fine granularity).
    /// * `hbm_bytes`: HBM ring capacity for spilled KV.
    /// * `bytes_per_token`: K+V bytes per token for this group's shard.
    /// * `max_tokens`: maximum request length (sizes the HBM buffers).
    pub fn new(
        sram_kv_bytes: u64,
        block_tokens: u64,
        hbm_bytes: u64,
        bytes_per_token: u64,
        max_tokens: u64,
    ) -> Self {
        let block_bytes = (block_tokens.max(1) * bytes_per_token).max(1);
        KvCache {
            sram: BlockAllocator::new(sram_kv_bytes, block_bytes),
            hbm: RingBuffer::new(hbm_bytes),
            bytes_per_token,
            max_request_bytes: max_tokens * bytes_per_token,
            entries: HashMap::new(),
            overflow_bytes: 0,
        }
    }

    /// Can another request be admitted? True when HBM can hold a whole
    /// max-length buffer (SRAM is best-effort and never blocks admission),
    /// or when there is no HBM at all (SRAM-only chips admit and may
    /// overflow — the WaferLLM regime, where overflow KV is remote SRAM).
    pub fn can_admit(&self) -> bool {
        self.hbm.capacity() == 0 || self.hbm.bytes_free() >= self.max_request_bytes
    }

    /// Admit a request: reserve its coarse-grained HBM buffer.
    pub fn admit(&mut self, id: u64) -> bool {
        if self.entries.contains_key(&id) {
            return true;
        }
        let hbm = if self.hbm.capacity() > 0 {
            match self.hbm.alloc(self.max_request_bytes) {
                Some(a) => Some(a),
                None => return false,
            }
        } else {
            None
        };
        self.entries.insert(
            id,
            Entry {
                chain: Chain::empty(),
                hbm,
                res: KvResidency::default(),
            },
        );
        true
    }

    /// Append `n_tokens` of KV for request `id`. New tokens fill SRAM
    /// blocks while any remain, then spill to the request's HBM buffer.
    pub fn append(&mut self, id: u64, n_tokens: u64) -> Appended {
        let bytes = n_tokens * self.bytes_per_token;
        let entry = self.entries.get_mut(&id).expect("append before admit");
        let mut out = Appended::default();
        // Fill the tail of the last SRAM block first.
        let chain_cap = entry.chain.n_blocks() as u64 * self.sram.block_bytes();
        let tail_room = chain_cap.saturating_sub(entry.res.sram_bytes);
        let into_tail = bytes.min(tail_room);
        out.sram_bytes += into_tail;
        let mut remaining = bytes - into_tail;
        // Grab new blocks while SRAM has them.
        while remaining > 0 && self.sram.append(&mut entry.chain) {
            let take = remaining.min(self.sram.block_bytes());
            out.sram_bytes += take;
            remaining -= take;
        }
        // Spill the rest to the HBM buffer.
        if remaining > 0 {
            match &entry.hbm {
                Some(a) => {
                    let room = a.bytes.saturating_sub(entry.res.hbm_bytes);
                    let take = remaining.min(room);
                    out.hbm_bytes += take;
                    self.overflow_bytes += remaining - take;
                }
                None => {
                    // SRAM-only chip: "spill" is remote/overflow, tracked so
                    // the executor can charge NoC offload (WaferLLM style).
                    out.hbm_bytes += remaining;
                }
            }
        }
        entry.res.sram_bytes += out.sram_bytes;
        entry.res.hbm_bytes += out.hbm_bytes;
        out
    }

    /// Current residency of a request's KV.
    pub fn residency(&self, id: u64) -> KvResidency {
        self.entries.get(&id).map(|e| e.res).unwrap_or_default()
    }

    /// Release all memory of a completed request.
    pub fn release(&mut self, id: u64) {
        if let Some(mut e) = self.entries.remove(&id) {
            self.sram.release(&mut e.chain);
            if let Some(a) = e.hbm {
                self.hbm.free(a.id);
            }
        }
    }

    pub fn n_active(&self) -> usize {
        self.entries.len()
    }

    /// Aggregate SRAM KV occupancy across requests.
    pub fn sram_used_bytes(&self) -> u64 {
        self.entries.values().map(|e| e.res.sram_bytes).sum()
    }

    pub fn sram_free_bytes(&self) -> u64 {
        self.sram.bytes_free()
    }

    pub fn hbm_free_bytes(&self) -> u64 {
        self.hbm.bytes_free()
    }

    /// Bytes lost to exhausted HBM buffers (must stay 0 when admission
    /// control sizes buffers by `max_tokens`).
    pub fn overflow_bytes(&self) -> u64 {
        self.overflow_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn cache() -> KvCache {
        // 4 blocks of 16 tokens × 8 B/token; HBM fits 4 requests of 256 tok.
        KvCache::new(4 * 16 * 8, 16, 4 * 256 * 8, 8, 256)
    }

    #[test]
    fn fills_sram_then_spills() {
        let mut kv = cache();
        assert!(kv.admit(1));
        // 64 tokens exactly fill SRAM (4 blocks × 16 tokens).
        let a = kv.append(1, 64);
        assert_eq!(a.sram_bytes, 64 * 8);
        assert_eq!(a.hbm_bytes, 0);
        // The next token spills.
        let a = kv.append(1, 10);
        assert_eq!(a.sram_bytes, 0);
        assert_eq!(a.hbm_bytes, 80);
        let r = kv.residency(1);
        assert_eq!(r.sram_bytes, 512);
        assert_eq!(r.hbm_bytes, 80);
    }

    #[test]
    fn partial_block_tail_is_reused() {
        let mut kv = cache();
        kv.admit(1);
        kv.append(1, 10); // block 0: 10/16 tokens used
        let a = kv.append(1, 4); // fits in block 0's tail
        assert_eq!(a.sram_bytes, 32);
        assert_eq!(kv.sram_free_bytes(), 3 * 16 * 8);
    }

    #[test]
    fn admission_bounded_by_hbm() {
        let mut kv = cache();
        for id in 0..4 {
            assert!(kv.can_admit(), "id={id}");
            assert!(kv.admit(id));
        }
        assert!(!kv.can_admit());
        assert!(!kv.admit(99));
        // Releasing one admits another.
        kv.release(0);
        assert!(kv.admit(99));
    }

    #[test]
    fn release_frees_both_tiers() {
        let mut kv = cache();
        kv.admit(1);
        kv.append(1, 100); // 64 SRAM + 36 spilled
        kv.admit(2);
        kv.append(2, 16); // all spilled (SRAM full)
        assert_eq!(kv.residency(2).sram_bytes, 0);
        kv.release(1);
        // New request can now use SRAM again.
        kv.admit(3);
        let a = kv.append(3, 16);
        assert_eq!(a.sram_bytes, 128);
    }

    #[test]
    fn sram_only_chip_tracks_remote_overflow() {
        let mut kv = KvCache::new(2 * 16 * 8, 16, 0, 8, 256);
        assert!(kv.can_admit());
        kv.admit(1);
        let a = kv.append(1, 48); // 32 tokens fit, 16 overflow "remote"
        assert_eq!(a.sram_bytes, 256);
        assert_eq!(a.hbm_bytes, 128);
        assert_eq!(kv.overflow_bytes(), 0);
    }

    #[test]
    fn prop_residency_equals_appended_tokens() {
        check("kv residency conservation", 64, |rng| {
            let mut kv = KvCache::new(
                rng.range_u64(0, 4096),
                rng.range_u64(1, 32),
                1 << 20,
                8,
                1024,
            );
            let mut expect: HashMap<u64, u64> = HashMap::new();
            for _ in 0..rng.range(1, 40) {
                let id = rng.range_u64(0, 4);
                if !kv.admit(id) {
                    continue;
                }
                let n = rng.range_u64(1, 64);
                let already = expect.entry(id).or_insert(0);
                if *already + n <= 1024 {
                    kv.append(id, n);
                    *already += n;
                }
            }
            for (id, tokens) in expect {
                assert_eq!(kv.residency(id).total(), tokens * 8, "id={id}");
            }
            assert_eq!(kv.overflow_bytes(), 0);
        });
    }
}
