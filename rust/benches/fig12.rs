//! `cargo bench` target regenerating the paper's fig12 (see
//! npusim::experiments). Prints the same rows the paper reports and
//! records wall time through the in-tree bench harness.

use npusim::experiments::{self, Opts};
use npusim::util::bench::Bench;

fn main() {
    let bench = Bench::new("fig12").iters(1).warmup(0);
    let opts = Opts::default();
    for id in ["fig12"].join(" ").split_whitespace() {
        bench.run(id, || {
            experiments::run(id, &opts).expect("experiment failed");
        });
    }
}
