"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracles.

hypothesis sweeps shapes/values; assert_allclose against ref.py is the
core correctness signal for everything the AOT artifacts compute.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import BLOCK_S, decode_attention
from compile.kernels.matmul import matmul, matmul_batched


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


class TestMatmul:
    @pytest.mark.parametrize(
        "m,k,n",
        [
            (128, 128, 128),  # exactly one tile
            (256, 128, 384),  # multi-tile grid
            (64, 64, 64),     # sub-tile (padding path)
            (130, 257, 100),  # ragged everything
            (1, 64, 256),     # GEMV-shaped
        ],
    )
    def test_matches_ref(self, m, k, n):
        x, w = rand(1, (m, k)), rand(2, (k, n))
        np.testing.assert_allclose(
            matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 200),
        k=st.integers(1, 200),
        n=st.integers(1, 200),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, m, k, n, seed):
        x = rand(seed, (m, k))
        w = rand(seed + 1, (k, n))
        np.testing.assert_allclose(
            matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
        )

    def test_batched_collapses_leading_dims(self):
        x, w = rand(3, (2, 5, 64)), rand(4, (64, 32))
        out = matmul_batched(x, w)
        assert out.shape == (2, 5, 32)
        np.testing.assert_allclose(
            out, ref.matmul_ref(x.reshape(10, 64), w).reshape(2, 5, 32), rtol=1e-4, atol=1e-4
        )

    def test_zero_input_gives_zero(self):
        out = matmul(jnp.zeros((16, 32)), rand(5, (32, 16)))
        assert float(jnp.abs(out).max()) == 0.0


class TestDecodeAttention:
    @pytest.mark.parametrize("b,h,kh,d,s", [(2, 4, 2, 16, 64), (1, 8, 8, 32, 128), (3, 4, 1, 16, 64)])
    def test_matches_ref(self, b, h, kh, d, s):
        q = rand(10, (b, h, d))
        k = rand(11, (b, s, kh, d))
        v = rand(12, (b, s, kh, d))
        kv_len = jnp.array([min(i * 7 + 1, s) for i in range(b)], jnp.int32)
        np.testing.assert_allclose(
            decode_attention(q, k, v, kv_len),
            ref.decode_attention_ref(q, k, v, kv_len),
            rtol=1e-4,
            atol=1e-4,
        )

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 3),
        groups=st.integers(1, 4),
        kh=st.sampled_from([1, 2, 4]),
        s_blocks=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, b, groups, kh, s_blocks, seed):
        h, d, s = groups * kh, 16, s_blocks * BLOCK_S
        q = rand(seed, (b, h, d))
        k = rand(seed + 1, (b, s, kh, d))
        v = rand(seed + 2, (b, s, kh, d))
        lens = jax.random.randint(jax.random.PRNGKey(seed + 3), (b,), 1, s + 1)
        np.testing.assert_allclose(
            decode_attention(q, k, v, lens),
            ref.decode_attention_ref(q, k, v, lens),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_mask_ignores_stale_kv(self):
        # Garbage beyond kv_len must not affect the output.
        b, h, kh, d, s = 1, 4, 2, 16, 64
        q = rand(20, (b, h, d))
        k = rand(21, (b, s, kh, d))
        v = rand(22, (b, s, kh, d))
        kv_len = jnp.array([10], jnp.int32)
        base = decode_attention(q, k, v, kv_len)
        k2 = k.at[:, 10:].set(1e9)
        v2 = v.at[:, 10:].set(-1e9)
        np.testing.assert_allclose(
            base, decode_attention(q, k2, v2, kv_len), rtol=1e-5, atol=1e-5
        )

    def test_single_valid_token_returns_its_value(self):
        b, h, kh, d, s = 1, 2, 2, 16, 64
        q = rand(30, (b, h, d))
        k = rand(31, (b, s, kh, d))
        v = rand(32, (b, s, kh, d))
        out = decode_attention(q, k, v, jnp.array([1], jnp.int32))
        np.testing.assert_allclose(out[0], v[0, 0], rtol=1e-5, atol=1e-5)


class TestSwiglu:
    @pytest.mark.parametrize("rows,inter", [(128, 128), (1, 64), (300, 96), (256, 512)])
    def test_matches_ref(self, rows, inter):
        from compile.kernels.swiglu import swiglu

        g, u = rand(40, (rows, inter), 3.0), rand(41, (rows, inter))
        np.testing.assert_allclose(
            swiglu(g, u), ref.swiglu_ref(g, u), rtol=1e-5, atol=1e-5
        )

    @settings(max_examples=15, deadline=None)
    @given(
        rows=st.integers(1, 300),
        inter=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, rows, inter, seed):
        from compile.kernels.swiglu import swiglu

        g, u = rand(seed, (rows, inter), 2.0), rand(seed + 1, (rows, inter))
        np.testing.assert_allclose(
            swiglu(g, u), ref.swiglu_ref(g, u), rtol=1e-5, atol=1e-5
        )

    def test_batched_shape(self):
        from compile.kernels.swiglu import swiglu_batched

        g, u = rand(42, (2, 7, 64)), rand(43, (2, 7, 64))
        out = swiglu_batched(g, u)
        assert out.shape == (2, 7, 64)
        np.testing.assert_allclose(
            out,
            ref.swiglu_ref(g, u),
            rtol=1e-5,
            atol=1e-5,
        )

    def test_extremes_are_stable(self):
        from compile.kernels.swiglu import swiglu

        g = jnp.array([[-100.0, 0.0, 100.0, -5.0]])
        u = jnp.ones((1, 4))
        out = np.asarray(swiglu(g, u))
        assert np.isfinite(out).all()
        assert abs(out[0, 0]) < 1e-6          # silu(-100) -> 0
        assert abs(out[0, 2] - 100.0) < 1e-3  # silu(100) -> 100
