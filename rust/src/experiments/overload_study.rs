//! `overload_study` — the SLO-aware control plane under a flash crowd:
//! a priority-mixed trace whose arrival spike runs at 2x the measured
//! sustainable service rate, replayed on a 2-chip cluster under three
//! admission policies:
//!
//! - `fifo`  — the legacy path: every priority flattened to normal, no
//!   shedding (`ShedPolicy::None`). Every request is admitted and the
//!   backlog blows through the TTFT SLO.
//! - `drop`  — priority classes + [`ShedPolicy::Drop`]: low/normal
//!   arrivals are refused while every chip is saturated; high-priority
//!   prefills may preempt low-priority decodes.
//! - `defer` — priority classes + [`ShedPolicy::Defer`]: the same
//!   admission check, but refused requests are re-timed past the backlog
//!   (bounded retries) instead of dropped outright.
//!
//! The TTFT SLO is calibrated, not hardcoded: a batch run measures one
//! chip's sustainable completion rate, and the SLO is a fixed number of
//! service periods (so the study is invariant to simulated chip speed).
//!
//! The acceptance property (gated via `BENCH_serving.json`'s `"slo"`
//! section): at 2x load, goodput-under-SLO with shedding + priorities
//! strictly exceeds the FIFO/no-shed baseline.
//!
//! ```sh
//! cargo run --release -p npusim -- experiment overload_study
//! ```

use crate::config::{ArrivalProcess, ChipConfig, LenDist, ModelConfig, PriorityMix, WorkloadConfig};
use crate::experiments::Opts;
use crate::serving::cluster::{self, ClusterConfig, RouterPolicy, ShedPolicy, ShedScope};
use crate::serving::faults::FaultSchedule;
use crate::serving::pd_fusion::FusionConfig;
use crate::serving::request::{self, Priority, Request};
use crate::serving::scheduler::SchedulerConfig;
use crate::util::table::{f3, Table};

/// The TTFT SLO in per-chip service periods: an unloaded request spends
/// ~1 period in service, so this allows a short admission queue and fails
/// the deep flash-crowd backlog.
pub const SLO_SERVICE_PERIODS: f64 = 6.0;
/// The TBT target of the goodput score (seconds) — generous on purpose:
/// overload shows up in admission latency (TTFT), not decode cadence.
pub const SLO_TBT_S: f64 = 0.25;

/// One measured admission-policy cell.
#[derive(Debug, Clone)]
pub struct OverloadRun {
    pub policy: &'static str,
    pub offered: usize,
    pub completed: usize,
    pub shed: u64,
    pub deferrals: u64,
    pub preemptions: u64,
    pub resumes: u64,
    /// The calibrated TTFT target this row was scored against (seconds).
    pub slo_ttft_s: f64,
    /// Output tokens/s over requests meeting the TTFT+TBT SLO.
    pub goodput_tok_s: f64,
    pub tok_s: f64,
    pub shed_rate: f64,
    pub ttft_p99_high_s: f64,
    pub ttft_p99_low_s: f64,
}

/// The per-chip scheduler of the study: one chip-wide fused pipeline, so
/// queue depth and KV pressure map 1:1 onto the chip's admission probes.
fn overload_sched() -> SchedulerConfig {
    SchedulerConfig::Fusion(FusionConfig {
        tp: 16,
        stages: 4,
        ..FusionConfig::default()
    })
}

/// Request shape of the study (lengths only; arrivals come later).
fn base_workload(n: usize) -> WorkloadConfig {
    let mut w = WorkloadConfig::fixed_ratio(384, 1, n);
    w.name = "overload".into();
    w.input_len = LenDist::Uniform(256, 512);
    w.output_len = LenDist::Uniform(16, 48);
    w
}

/// Measure the sustainable service rate (completed requests/s) of one
/// chip given the whole trace up front — the denominator behind "2x"
/// and the unit of the TTFT SLO.
pub fn sustainable_rate(model: &ModelConfig, n: usize) -> anyhow::Result<f64> {
    let w = base_workload(n).with_arrival(ArrivalProcess::Batch);
    let cfg = ClusterConfig::new(
        ChipConfig::large_core(),
        1,
        overload_sched(),
        RouterPolicy::RoundRobin,
    );
    let cm = cluster::simulate_cluster(&cfg, model, &w)?;
    let rate = cm.aggregate().requests_per_s();
    anyhow::ensure!(rate > 0.0, "calibration run completed no requests");
    Ok(rate)
}

/// The flash-crowd trace: Poisson warmup at half the cluster's sustained
/// rate, then a spike at `overload_factor`× it until the request budget
/// is spent. 20% high / 30% low priority mass.
pub fn flash_crowd_trace(n: usize, cluster_rate: f64, overload_factor: f64) -> Vec<Request> {
    let peak = (cluster_rate * overload_factor).max(1.0);
    let w = base_workload(n)
        .with_arrival(ArrivalProcess::FlashCrowd {
            base_rate: (cluster_rate * 0.5).max(1.0),
            peak_rate: peak,
            spike_start_s: 0.05,
            // Long enough that every remaining request lands inside it.
            spike_len_s: n as f64 / peak + 1.0,
        })
        .with_priority_mix(PriorityMix { high: 0.2, low: 0.3 });
    request::generate(&w)
}

/// Run one admission policy over `reqs` on a 2-chip cluster.
fn run_policy(
    policy: &'static str,
    model: &ModelConfig,
    reqs: Vec<Request>,
    shed: ShedPolicy,
    queue_cap: usize,
    slo_ttft_s: f64,
) -> anyhow::Result<OverloadRun> {
    run_policy_scoped(
        policy,
        model,
        reqs,
        shed,
        queue_cap,
        slo_ttft_s,
        ShedScope::Global,
        RouterPolicy::LeastLoaded,
        None,
    )
}

/// [`run_policy`] with an explicit shed scope, router, and (optionally) a
/// fault schedule — the per-chip-scope satellite compares scopes on a
/// cluster with one deliberately HBM-throttled chip.
#[allow(clippy::too_many_arguments)]
fn run_policy_scoped(
    policy: &'static str,
    model: &ModelConfig,
    reqs: Vec<Request>,
    shed: ShedPolicy,
    queue_cap: usize,
    slo_ttft_s: f64,
    scope: ShedScope,
    router: RouterPolicy,
    faults: Option<FaultSchedule>,
) -> anyhow::Result<OverloadRun> {
    let offered = reqs.len();
    let mut cfg = ClusterConfig::new(ChipConfig::large_core(), 2, overload_sched(), router)
        .with_shed(shed, queue_cap)
        .with_shed_scope(scope);
    if let Some(f) = faults {
        cfg = cfg.with_faults(f);
    }
    cfg.slo_ttft_s = slo_ttft_s;
    let cm = cluster::simulate_cluster_requests(&cfg, model, reqs)?;
    let agg = cm.aggregate();
    anyhow::ensure!(
        agg.n_requests() as u64 + agg.control.shed_requests == offered as u64,
        "{policy}: {} completed + {} shed != {offered} offered",
        agg.n_requests(),
        agg.control.shed_requests
    );
    Ok(OverloadRun {
        policy,
        offered,
        completed: agg.n_requests(),
        shed: agg.control.shed_requests,
        deferrals: agg.control.deferrals,
        preemptions: agg.control.preemptions,
        resumes: agg.control.resumes,
        slo_ttft_s,
        goodput_tok_s: agg.goodput_tokens_per_s(slo_ttft_s, SLO_TBT_S),
        tok_s: agg.tokens_per_s(),
        shed_rate: agg.shed_rate(),
        ttft_p99_high_s: agg.ttft_s_of(Priority::High).p99(),
        ttft_p99_low_s: agg.ttft_s_of(Priority::Low).p99(),
    })
}

/// The three-policy comparison the bench's `"slo"` section reports: the
/// same flash-crowd arrivals and lengths under `fifo` (priorities
/// flattened, no shedding), `drop`, and `defer`.
pub fn bench_rows(opts: &Opts) -> anyhow::Result<Vec<OverloadRun>> {
    let model = ModelConfig::qwen3_4b();
    let n = opts.pick(96, 24);
    // Calibrate on a shorter batch; the rate is per chip, the cluster
    // runs two, and "2x load" means 2x the whole cluster's capacity.
    let per_chip = sustainable_rate(&model, opts.pick(24, 8))?;
    let slo_ttft_s = SLO_SERVICE_PERIODS / per_chip;
    // Backlog depth scales with spike *length* (excess arrivals pile up
    // for its whole duration), so the compressed fast trace needs a
    // proportionally harsher spike to overrun the same SLO.
    let factor = opts.pick(2.0, 6.0);
    let reqs = flash_crowd_trace(n, per_chip * 2.0, factor);
    // The FIFO baseline replays the *identical* arrivals and lengths with
    // the class labels erased, so the comparison isolates the control
    // plane (not the trace).
    let fifo_reqs: Vec<Request> = reqs
        .iter()
        .map(|r| Request {
            priority: Priority::Normal,
            ..*r
        })
        .collect();
    let cap = 4;
    Ok(vec![
        run_policy("fifo", &model, fifo_reqs, ShedPolicy::None, cap, slo_ttft_s)?,
        run_policy("drop", &model, reqs.clone(), ShedPolicy::Drop, cap, slo_ttft_s)?,
        run_policy("defer", &model, reqs, ShedPolicy::Defer, cap, slo_ttft_s)?,
    ])
}

/// Satellite comparison: global vs per-chip shed scope on a cluster whose
/// chip 0 is HBM-throttled for the whole run, behind a state-blind
/// round-robin router. The global scope only sheds when *every* chip is
/// saturated, so round-robin keeps piling arrivals onto the slow chip's
/// queue (deep TTFT misses); the per-chip scope sheds exactly the
/// arrivals routed at the saturated chip, bounding its queue without
/// gating the healthy chip's admissions.
pub fn scope_rows(opts: &Opts) -> anyhow::Result<Vec<OverloadRun>> {
    let model = ModelConfig::qwen3_4b();
    let n = opts.pick(96, 24);
    let per_chip = sustainable_rate(&model, opts.pick(24, 8))?;
    let slo_ttft_s = SLO_SERVICE_PERIODS / per_chip;
    let reqs = flash_crowd_trace(n, per_chip * 2.0, opts.pick(2.0, 6.0));
    // One chip at ~1/3 memory bandwidth from t=0 for the whole trace.
    let throttle = FaultSchedule::parse("hbm:0@0.0001:0.35:1000")?;
    let cap = 4;
    let mut rows = Vec::new();
    for (name, scope) in [("global", ShedScope::Global), ("per-chip", ShedScope::PerChip)] {
        rows.push(run_policy_scoped(
            name,
            &model,
            reqs.clone(),
            ShedPolicy::Drop,
            cap,
            slo_ttft_s,
            scope,
            RouterPolicy::RoundRobin,
            Some(throttle.clone()),
        )?);
    }
    Ok(rows)
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let runs = bench_rows(opts)?;

    let mut t = Table::new(
        "overload_study — flash crowd at 2x sustainable rate (Qwen3-4B, 2 large-core chips)",
        &[
            "policy",
            "offered",
            "completed",
            "shed",
            "deferrals",
            "preempt/resume",
            "goodput tok/s (SLO)",
            "tok/s",
            "TTFT p99 high (s)",
            "TTFT p99 low (s)",
        ],
    );
    for r in &runs {
        t.row(&[
            r.policy.to_string(),
            r.offered.to_string(),
            r.completed.to_string(),
            format!("{} ({:.0}%)", r.shed, r.shed_rate * 100.0),
            r.deferrals.to_string(),
            format!("{}/{}", r.preemptions, r.resumes),
            f3(r.goodput_tok_s),
            f3(r.tok_s),
            f3(r.ttft_p99_high_s),
            f3(r.ttft_p99_low_s),
        ]);
    }

    let fifo = runs.iter().find(|r| r.policy == "fifo").unwrap();
    let shed = runs.iter().find(|r| r.policy == "drop").unwrap();
    println!(
        "overload_study: goodput under SLO (TTFT<{:.4}s) — fifo {:.1} tok/s vs \
         drop {:.1} tok/s ({:.2}x), shedding {:.0}% of offered load",
        fifo.slo_ttft_s,
        fifo.goodput_tok_s,
        shed.goodput_tok_s,
        if fifo.goodput_tok_s > 0.0 {
            shed.goodput_tok_s / fifo.goodput_tok_s
        } else {
            f64::INFINITY
        },
        shed.shed_rate * 100.0
    );

    let scopes = scope_rows(opts)?;
    let mut ts = Table::new(
        "overload_study — shed scope with one HBM-throttled chip (round-robin router)",
        &[
            "scope",
            "offered",
            "completed",
            "shed",
            "goodput tok/s (SLO)",
            "tok/s",
            "TTFT p99 low (s)",
        ],
    );
    for r in &scopes {
        ts.row(&[
            r.policy.to_string(),
            r.offered.to_string(),
            r.completed.to_string(),
            format!("{} ({:.0}%)", r.shed, r.shed_rate * 100.0),
            f3(r.goodput_tok_s),
            f3(r.tok_s),
            f3(r.ttft_p99_low_s),
        ]);
    }

    Ok(vec![t, ts])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flash_crowd_trace_is_deterministic_and_mixed() {
        let reqs = flash_crowd_trace(48, 100.0, 2.0);
        assert_eq!(reqs.len(), 48);
        assert_eq!(reqs, flash_crowd_trace(48, 100.0, 2.0));
        // Arrivals stay sorted (the cluster driver requires it).
        assert!(reqs.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        // The 0.2:0.3 mix realises every class at this size.
        for class in Priority::ALL {
            assert!(
                reqs.iter().any(|r| r.priority == class),
                "no {class:?} request in the trace"
            );
        }
    }

    #[test]
    fn shedding_beats_fifo_on_goodput_under_overload() {
        // The acceptance property at fast scale: the priority+shed control
        // plane must strictly beat the no-shed FIFO baseline on
        // goodput-under-SLO when offered overload, and the offered =
        // completed + shed conservation must hold per policy (checked
        // inside run_policy).
        let runs = bench_rows(&Opts::fast()).unwrap();
        assert_eq!(runs.len(), 3);
        let by = |p: &str| runs.iter().find(|r| r.policy == p).unwrap();
        let (fifo, dropped, deferred) = (by("fifo"), by("drop"), by("defer"));
        assert_eq!(fifo.shed, 0, "fifo must never shed");
        assert_eq!(fifo.completed, fifo.offered);
        assert!(dropped.shed > 0, "overload never tripped the shedder");
        assert!(
            dropped.goodput_tok_s > fifo.goodput_tok_s,
            "drop goodput {} !> fifo {}",
            dropped.goodput_tok_s,
            fifo.goodput_tok_s
        );
        // Defer holds on to work instead of dropping it: it retries and
        // completes at least as many requests as drop.
        assert!(deferred.deferrals > 0, "defer never deferred");
        assert!(deferred.completed >= dropped.completed);
    }

    #[test]
    fn per_chip_shedding_never_reduces_goodput_vs_global() {
        // The satellite acceptance property: scoping the shed decision to
        // the routed chip's queue (instead of demanding cluster-wide
        // saturation) must not cost goodput — with one throttled chip
        // behind a state-blind router it should gain, because the global
        // scope keeps admitting onto the slow chip's deep queue.
        let rows = scope_rows(&Opts::fast()).unwrap();
        let by = |p: &str| rows.iter().find(|r| r.policy == p).unwrap();
        let (global, per_chip) = (by("global"), by("per-chip"));
        // Conservation per scope is asserted inside run_policy_scoped.
        assert!(per_chip.shed > 0, "the throttled chip never tripped its shedder");
        assert!(
            per_chip.goodput_tok_s >= global.goodput_tok_s,
            "per-chip goodput {} < global {}",
            per_chip.goodput_tok_s,
            global.goodput_tok_s
        );
    }
}
