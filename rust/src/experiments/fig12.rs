//! Fig. 12 — heterogeneous decode cores for PD disaggregation: sweep the
//! decode cores' systolic-array dimension and per-core HBM bandwidth;
//! report throughput, TBT, and both per unit of chip area (7nm area model).
//!
//! Prefill:decode core ratio fixed at 2:1 (the Fig. 11 optimum).

use crate::area;
use crate::config::{ChipConfig, ModelConfig, WorkloadConfig};
use crate::experiments::Opts;
use crate::serving::metrics::Metrics;
use crate::serving::pd_disagg::{simulate_disagg, DisaggConfig};
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};

/// The decode-core configurations of the sweep: (name, sa_dim, hbm GB/s).
/// Config 0 is the homogeneous baseline (A64H120 matches prefill cores on
/// the large-core chip at 120 GB/s).
pub const CONFIGS: [(&str, u64, f64); 8] = [
    ("A128H120 (homog)", 128, 120.0),
    ("A128H240", 128, 240.0),
    ("A128H480", 128, 480.0),
    ("A64H120", 64, 120.0),
    ("A64H240", 64, 240.0),
    ("A64H480", 64, 480.0),
    ("A32H60", 32, 60.0),
    ("A32H240", 32, 240.0),
];

pub fn run_config(
    model: &ModelConfig,
    w: &WorkloadConfig,
    sa: u64,
    hbm: f64,
) -> anyhow::Result<(Metrics, f64)> {
    let mut decode_core = ChipConfig::large_core().core;
    decode_core.sa_dim = sa;
    decode_core.hbm_bw_gbps = hbm;
    // SRAM bandwidth auto-scales with the systolic array (Table 3 note).
    let chip_cfg = ChipConfig::large_core().with_decode_core(decode_core);
    let cfg = DisaggConfig::ratio_64(42, 21, 6); // 2:1 ratio
    let area = area::chip_area_mm2(&chip_cfg, cfg.n_decode);
    let mut chip = ChipSim::new(chip_cfg);
    let m = simulate_disagg(&mut chip, model, w, &cfg)?;
    Ok((m, area))
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let model = ModelConfig::qwen3_4b();
    let n = opts.pick(24, 4);
    // Decode-leaning workload exposes the decode cores' provisioning.
    let w = WorkloadConfig::fixed_ratio(opts.pick(256, 64), opts.pick(256, 24), n);
    let configs: Vec<&(&str, u64, f64)> = if opts.fast {
        CONFIGS.iter().take(3).collect()
    } else {
        CONFIGS.iter().collect()
    };

    let mut t = Table::new(
        "Fig 12 — heterogeneous decode cores (P42/D21, Qwen3-4B)",
        &[
            "decode config",
            "tok/s",
            "area mm2",
            "tok/s/mm2 (norm)",
            "TBT (ms)",
            "1/(TBT*area) (norm)",
        ],
    );
    let mut rows: Vec<(String, f64, f64, f64)> = Vec::new();
    for &&(name, sa, hbm) in &configs {
        let (m, area) = run_config(&model, &w, sa, hbm)?;
        rows.push((name.to_string(), m.tokens_per_s(), area, m.tbt_s().mean()));
    }
    let (base_tps, base_area, base_tbt) = (rows[0].1, rows[0].2, rows[0].3);
    for (name, tps, area, tbt) in &rows {
        t.row(&[
            name.clone(),
            f3(*tps),
            f3(*area),
            f3((tps / area) / (base_tps / base_area)),
            f3(tbt * 1e3),
            f3((base_tbt * base_area) / (tbt * area)),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_decode_hbm_bw_helps_throughput() {
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(64, 32, 6);
        let (lo, _) = run_config(&model, &w, 128, 60.0).unwrap();
        let (hi, _) = run_config(&model, &w, 128, 480.0).unwrap();
        assert!(
            hi.tokens_per_s() >= lo.tokens_per_s(),
            "hbm480 {} vs hbm60 {}",
            hi.tokens_per_s(),
            lo.tokens_per_s()
        );
    }

    #[test]
    fn narrower_decode_array_wins_per_area() {
        // §4.3.1: decode is GEMV-bound, so halving the array barely hurts
        // throughput while shrinking area → better tput/mm².
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(64, 32, 6);
        let (wide, area_wide) = run_config(&model, &w, 128, 240.0).unwrap();
        let (narrow, area_narrow) = run_config(&model, &w, 32, 240.0).unwrap();
        let per_area_wide = wide.tokens_per_s() / area_wide;
        let per_area_narrow = narrow.tokens_per_s() / area_narrow;
        assert!(
            per_area_narrow > per_area_wide,
            "narrow {per_area_narrow} vs wide {per_area_wide}"
        );
    }

    #[test]
    fn table_shape() {
        let tables = run(&Opts::fast()).unwrap();
        assert_eq!(tables[0].n_rows(), 3);
    }
}
