//! PD fusion behind the [`Scheduler`] trait: every pipeline co-locates
//! chunked prefill and decode under a per-iteration token budget
//! (§4.3.2). The policy logic lives in [`super::pipe`]; this type owns the
//! pipeline set, static request assignment, and earliest-actionable-pipe
//! selection.

use super::pipe::{self, Pipe};
use super::Scheduler;
use crate::config::ModelConfig;
use crate::memmgr::prefix::BlockKey;
use crate::serving::metrics::Metrics;
use crate::serving::pd_fusion::FusionConfig;
use crate::serving::request::Request;
use crate::sim::chip::ChipSim;
use crate::util::units::Cycle;

/// The fused scheduler: N identical pipelines, requests statically
/// round-robined across them, decode-first budget batching within each.
pub struct FusionScheduler {
    cfg: FusionConfig,
    pipes: Vec<Pipe>,
    /// Round-robin cursor: the pipe the next [`Scheduler::enqueue`] targets.
    next_pipe: usize,
}

impl FusionScheduler {
    pub fn new(cfg: FusionConfig) -> Self {
        FusionScheduler {
            cfg,
            pipes: Vec::new(),
            next_pipe: 0,
        }
    }

    /// Number of data-parallel pipelines after `init`.
    pub fn n_pipelines(&self) -> usize {
        self.pipes.len()
    }
}

impl Scheduler for FusionScheduler {
    fn name(&self) -> &'static str {
        "fusion"
    }

    fn prepare(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        max_tokens: usize,
    ) -> anyhow::Result<()> {
        self.pipes = pipe::build_pipes(chip, model, &self.cfg, max_tokens.max(1))?;
        self.next_pipe = 0;
        Ok(())
    }

    fn enqueue(&mut self, req: Request) {
        let n = self.pipes.len();
        self.pipes[self.next_pipe % n].queue.push_back(req);
        self.next_pipe = (self.next_pipe + 1) % n;
    }

    fn step(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        metrics: &mut Metrics,
    ) -> anyhow::Result<usize> {
        let freq = chip.cfg.freq_mhz;
        // Pick the pipeline with the earliest actionable work.
        let (pi, t) = self
            .pipes
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.next_action(chip, freq).map(|t| (i, t)))
            .min_by_key(|&(_, t)| t)
            .ok_or_else(|| anyhow::anyhow!("fusion deadlock: no actionable pipeline"))?;
        let mut no_handoffs = Vec::new();
        Ok(self.pipes[pi].tick(
            chip,
            model,
            &self.cfg,
            t,
            metrics,
            freq,
            false,
            &mut no_handoffs,
        ))
    }

    fn next_action(&self, chip: &ChipSim) -> Option<Cycle> {
        pipe::earliest_action(&self.pipes, chip)
    }

    fn pending_work(&self) -> usize {
        pipe::total_pending(&self.pipes)
    }

    fn kv_utilization(&self) -> f64 {
        pipe::mean_kv_utilization(&self.pipes)
    }

    fn probe_prefix(&self, keys: &[BlockKey], limit: u64, at: Cycle) -> u64 {
        pipe::best_prefix_match(&self.pipes, keys, limit, at)
    }

    fn import_prefix(&mut self, keys: &[BlockKey], ready_at: Cycle) {
        pipe::seed_all(&mut self.pipes, keys, ready_at);
    }

    fn collect_cache_stats(&self, out: &mut crate::serving::metrics::CacheStats) {
        for p in &self.pipes {
            p.collect_cache_stats(out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChipConfig, WorkloadConfig};
    use crate::serving::scheduler::simulate;

    #[test]
    fn small_max_batch_does_not_starve_requests() {
        // Admission back-pressure (max_batch 2, 10 requests): every request
        // must still retire exactly once — queued requests are admitted as
        // earlier ones release their KV.
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(128, 8, 10);
        let cfg = FusionConfig {
            max_batch: 2,
            ..FusionConfig::default()
        };
        let mut sched = FusionScheduler::new(cfg);
        let m = simulate(&mut chip, &model, &w, &mut sched).unwrap();
        assert_eq!(m.n_requests(), 10);
        let mut ids: Vec<u64> = m.records().iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn layout_reported_after_init() {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        let mut sched = FusionScheduler::new(FusionConfig::default());
        sched
            .init(&mut chip, &model, Vec::new())
            .expect("layout fits");
        // 8x8 chip, TP=4 (2x2 cells), 4 stages -> 4 data-parallel pipes.
        assert_eq!(sched.n_pipelines(), 4);
    }
}
