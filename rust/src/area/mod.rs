//! Chip area model (Fig. 12's "per unit of chip area" metrics).
//!
//! The paper computes "chip area per unit of computational power, HBM
//! interface and SRAM" from TSMC's 7nm process. The absolute constants
//! here are derived from public 7nm literature (A100/Ascend die analyses,
//! HBM2e PHY area reports); Fig. 12's rankings depend only on the
//! *relative* ratios between MACs, SRAM macros and HBM PHYs, which these
//! preserve (DESIGN.md "Substitutions").

use crate::config::{ChipConfig, CoreConfig};

/// mm² per bf16 MAC at 7nm (systolic array cell incl. local routing):
/// ~0.25 mm² per 1024-MAC tile.
pub const MM2_PER_MAC: f64 = 0.25 / 1024.0;

/// mm² per vector ALU (wider datapath + register files than a MAC).
pub const MM2_PER_VALU: f64 = 0.6 / 1024.0;

/// mm² per MB of SRAM at 7nm (dense macro ≈ 0.45 mm²/MB incl. periphery).
pub const MM2_PER_MB_SRAM: f64 = 0.45;

/// mm² of HBM PHY + controller per GB/s of per-core bandwidth
/// (HBM2e PHY ≈ 11 mm² per 450 GB/s stack interface).
pub const MM2_PER_GBPS_HBM: f64 = 11.0 / 450.0;

/// mm² of NoC router + link drivers per GB/s of per-link bandwidth.
pub const MM2_PER_GBPS_NOC: f64 = 0.35 / 128.0;

/// Fixed per-core overhead (scalar core, DMA engines, control): mm².
pub const MM2_CORE_OVERHEAD: f64 = 0.3;

/// Area of one NPU core in mm².
pub fn core_area_mm2(core: &CoreConfig, noc_link_gbps: f64) -> f64 {
    let macs = (core.sa_dim * core.sa_dim) as f64 * MM2_PER_MAC;
    let valus = (core.vector_lanes * 64) as f64 * MM2_PER_VALU;
    let sram = core.sram_bytes as f64 / (1024.0 * 1024.0) * MM2_PER_MB_SRAM;
    let hbm = core.hbm_bw_gbps * MM2_PER_GBPS_HBM;
    let noc = 4.0 * noc_link_gbps * MM2_PER_GBPS_NOC;
    macs + valus + sram + hbm + noc + MM2_CORE_OVERHEAD
}

/// Total chip area in mm² (honouring heterogeneous decode cores when
/// `n_decode_cores` of the chip use the decode-core override).
pub fn chip_area_mm2(chip: &ChipConfig, n_decode_cores: usize) -> f64 {
    let n = chip.n_cores();
    let nd = n_decode_cores.min(n);
    let np = n - nd;
    let link = chip.noc.link_bw_gbps;
    np as f64 * core_area_mm2(&chip.core, link)
        + nd as f64 * core_area_mm2(&chip.decode_core(), link)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::MB;

    #[test]
    fn homogeneous_chip_area_scales_with_cores() {
        let large = ChipConfig::large_core();
        let a64 = chip_area_mm2(&large, 0);
        assert!((a64 / 64.0 - core_area_mm2(&large.core, large.noc.link_bw_gbps)).abs() < 1e-9);
        assert!(a64 > 100.0 && a64 < 5000.0, "implausible area {a64}");
    }

    #[test]
    fn narrower_decode_cores_shrink_the_chip() {
        let chip = ChipConfig::large_core();
        let mut decode = chip.core;
        decode.sa_dim = 32; // 1/16 the MACs
        let hetero = chip.clone().with_decode_core(decode);
        assert!(chip_area_mm2(&hetero, 21) < chip_area_mm2(&chip, 0));
    }

    #[test]
    fn hbm_bandwidth_costs_area() {
        let mut a = ChipConfig::large_core().core;
        let mut b = a;
        a.hbm_bw_gbps = 60.0;
        b.hbm_bw_gbps = 480.0;
        assert!(core_area_mm2(&b, 128.0) > core_area_mm2(&a, 128.0));
    }

    #[test]
    fn sram_dominates_when_huge() {
        let mut small = ChipConfig::large_core().core;
        small.sram_bytes = 8 * MB;
        let mut big = small;
        big.sram_bytes = 128 * MB;
        let delta = core_area_mm2(&big, 128.0) - core_area_mm2(&small, 128.0);
        assert!((delta - 120.0 * MM2_PER_MB_SRAM).abs() < 1e-9);
    }
}
