//! Distributed execution of one serving iteration on a placed TP group.
//!
//! Every operator of the transformer layer is executed with the group's
//! partition strategy (Fig. 3): what each core computes comes from the
//! shape math, what the group communicates comes from the ring collectives
//! running on the contention-aware NoC — so placement quality (Fig. 4/10)
//! and NoC bandwidth show up in end-to-end iteration latency exactly as in
//! the paper.

use crate::config::{ChipConfig, ModelConfig};
use crate::memmgr::{KvCache, SramPlan};
use crate::model::batch::IterBatch;
use crate::model::memo::{LatencyMemo, MemoEntry};
use crate::parallel::collectives::{ring_all_reduce, ring_step, sub_ring_all_reduce};
use crate::parallel::partition::PartitionStrategy;
use crate::parallel::placement::{Placement, TpGroup};
use crate::sim::chip::ChipSim;
use crate::sim::compute;
use crate::sim::tracer::{OpClass, OP_CLASSES};
use crate::util::units::{ceil_div, Cycle};

/// Static execution configuration for a worker group.
#[derive(Debug, Clone, Copy)]
pub struct ExecConfig {
    /// GEMM partition strategy within the group.
    pub strategy: PartitionStrategy,
    /// Phase-aware partition switch (Fig. 9): `Some((small, thresh))`
    /// routes GEMMs whose M dimension is below `thresh` to `small` instead
    /// of [`ExecConfig::strategy`] — the K partition moves results
    /// (`M·N`) instead of weights (`K·N`), so it wins for decode steps and
    /// short chunks while AllGather/2-D win for long prefill. `None`
    /// (the default) is the static pre-plan behaviour, bit-identical.
    pub small_m: Option<(PartitionStrategy, u64)>,
    /// Transformer layers this group executes per iteration (its pipeline
    /// stage depth).
    pub layers: usize,
    /// Whether this group computes output logits (last pipeline stage).
    pub with_logits: bool,
}

impl ExecConfig {
    pub fn new(strategy: PartitionStrategy, layers: usize, with_logits: bool) -> Self {
        ExecConfig {
            strategy,
            small_m: None,
            layers,
            with_logits,
        }
    }

    /// Enable the phase-aware switch (builder style). A `threshold` of 0
    /// disables it (every GEMM keeps [`ExecConfig::strategy`]).
    pub fn with_small_m(mut self, small: PartitionStrategy, threshold: u64) -> Self {
        self.small_m = (threshold > 0).then_some((small, threshold));
        self
    }

    /// The partition strategy a GEMM of `m` rows runs with under this
    /// config — what every [`dist_gemm`] call inside the iteration uses.
    pub fn strategy_for(&self, m: u64) -> PartitionStrategy {
        match self.small_m {
            Some((small, thresh)) if m < thresh => small,
            _ => self.strategy,
        }
    }
}

/// Max clock over the group (iteration makespan so far).
pub fn group_now(chip: &ChipSim, group: &TpGroup) -> Cycle {
    group
        .coords
        .iter()
        .map(|&c| chip.core(c).now())
        .max()
        .unwrap_or(0)
}

/// Advance every core of the group by `cycles` of `class` work from the
/// synchronised time `t0` (lock-step TP execution).
fn uniform_op(chip: &mut ChipSim, group: &TpGroup, class: OpClass, t0: Cycle, cycles: Cycle) {
    for &c in &group.coords {
        let core = chip.core_mut(c);
        core.advance_to(t0);
        if cycles > 0 {
            core.tracer.record(class, cycles);
        }
        core.advance_to(t0 + cycles);
    }
}

/// One distributed GEMM `[m,k] × [k,n]` over the group.
///
/// `hbm_weight_bytes` is the per-core portion of this GEMM's weight shard
/// that is *not* SRAM-resident and must stream from HBM (charged once; in
/// rotating strategies only a core's own shard lives in its HBM — gathered
/// shards arrive over the NoC).
pub fn dist_gemm(
    chip: &mut ChipSim,
    group: &TpGroup,
    strategy: PartitionStrategy,
    m: u64,
    k: u64,
    n: u64,
    hbm_weight_bytes: u64,
) -> Cycle {
    if m == 0 || k == 0 || n == 0 {
        return group_now(chip, group);
    }
    let cfg = chip.cfg.clone();
    let num = group.len().max(1) as u64;
    let dtype = cfg.dtype_bytes;
    match strategy {
        PartitionStrategy::InputOnly => {
            let m_loc = ceil_div(m, num);
            for &c in &group.coords {
                chip.core_mut(c)
                    .gemm_hbm_weights(&cfg, m_loc, k, n, hbm_weight_bytes);
            }
            chip.sync(&group.coords)
        }
        PartitionStrategy::OneDimMN => {
            // Rotating AllGather (T10/WaferLLM style): each core computes
            // its M-rows against the weight shard it currently holds while
            // the next shard rotates in; `num` steps overlap compute+comm.
            let m_loc = ceil_div(m, num);
            let n_loc = ceil_div(n, num);
            let shard_bytes = k * n_loc * dtype;
            for step in 0..num {
                let t0 = chip.sync(&group.coords);
                let hbm = if step == 0 { hbm_weight_bytes } else { 0 };
                // Compute this step's partial GEMM (with the first step
                // streaming the core's own shard from HBM if not resident).
                let mut t_comp_end = t0;
                for &c in &group.coords {
                    let core = chip.core_mut(c);
                    core.gemm_hbm_weights(&cfg, m_loc, k, n_loc, hbm);
                    t_comp_end = t_comp_end.max(core.now());
                }
                // Rotate shards (skipped on the last step) — issued from
                // t0 so transfer overlaps the step's compute (dataflow DMA).
                if step + 1 < num {
                    for &c in &group.coords {
                        chip.core_mut(c).advance_to(t0); // cannot go back; no-op
                    }
                    // Issue the ring transfers at each core's *pre-compute*
                    // clock by temporarily using mesh directly.
                    let nloc = group.len();
                    let mut barrier = t0;
                    for i in 0..nloc {
                        let src = group.coords[i];
                        let dst = group.coords[(i + 1) % nloc];
                        let t = chip.mesh.transfer(src, dst, shard_bytes, t0);
                        chip.core_mut(src)
                            .tracer
                            .record(OpClass::AllGather, t.finish - t0);
                        barrier = barrier.max(t.finish);
                    }
                    let next = barrier.max(t_comp_end);
                    for &c in &group.coords {
                        chip.core_mut(c).advance_to(next);
                    }
                } else {
                    for &c in &group.coords {
                        chip.core_mut(c).advance_to(t_comp_end);
                    }
                }
            }
            group_now(chip, group)
        }
        PartitionStrategy::OneDimK => {
            // Local partial GEMM over the K-shard, then ring AllReduce of
            // the full [m,n] partial results.
            let k_loc = ceil_div(k, num);
            for &c in &group.coords {
                chip.core_mut(c)
                    .gemm_hbm_weights(&cfg, m, k_loc, n, hbm_weight_bytes);
            }
            ring_all_reduce(chip, group, m * n * dtype)
        }
        PartitionStrategy::TwoDim { rows, cols } => {
            let grid = group.mesh_grid(rows, cols);
            let (r, c_) = (rows as u64, cols as u64);
            let m_loc = ceil_div(m, r);
            let k_loc = ceil_div(k, c_);
            let n_loc = ceil_div(n, r);
            // Column rotation shard (Table 2: (R-1) · K·N/(C·R) total).
            let col_shard = k * n / (r * c_) * dtype;
            // Row partial-result reduction (Table 2: 2·(C-1)/C · M·N/C²).
            let row_data = m * n / (c_ * c_) * dtype;
            for it in 0..rows {
                let t0 = chip.sync(&group.coords);
                let hbm = if it == 0 { hbm_weight_bytes } else { 0 };
                let mut t_comp_end = t0;
                for &coord in grid.iter().flatten() {
                    let core = chip.core_mut(coord);
                    core.gemm_hbm_weights(&cfg, m_loc, k_loc, n_loc, hbm);
                    t_comp_end = t_comp_end.max(core.now());
                }
                for &coord in grid.iter().flatten() {
                    chip.core_mut(coord).advance_to(t_comp_end);
                }
                if it + 1 < rows {
                    // Row-wise AllReduce of partial results.
                    for row in &grid {
                        sub_ring_all_reduce(chip, row, row_data);
                    }
                    // Column-wise shard rotation (AllGather step).
                    for j in 0..cols {
                        let col: Vec<_> = grid.iter().map(|row| row[j]).collect();
                        let col_group = TpGroup {
                            coords: col,
                            placement: Placement::Ring,
                        };
                        ring_step(chip, &col_group, col_shard, OpClass::AllGather);
                    }
                }
            }
            chip.sync(&group.coords)
        }
    }
}

/// Attention over every batch item (heads sharded across the group; each
/// core holds its head-shard of each request's KV, with the spilled portion
/// streaming from HBM).
fn attention_all(
    chip: &mut ChipSim,
    group: &TpGroup,
    cfg: &ChipConfig,
    model: &ModelConfig,
    batch: &IterBatch,
    kv: &KvCache,
    layers: usize,
) -> Cycle {
    let tp = group.len().max(1) as u64;
    let heads = ceil_div(model.heads as u64, tp).max(1);
    let t0 = chip.sync(&group.coords);
    for &c in &group.coords {
        let core = chip.core_mut(c);
        for item in &batch.items {
            let res = kv.residency(item.request);
            // The KV residency covers all `layers` of this group's shard;
            // charge one layer's share per attention call.
            let kv_hbm = res.hbm_bytes / layers.max(1) as u64;
            core.attention(
                cfg,
                heads,
                item.q_tokens,
                item.kv_tokens,
                model.head_dim as u64,
                kv_hbm,
            );
        }
    }
    let t = group_now(chip, group);
    for &c in &group.coords {
        chip.core_mut(c).advance_to(t);
    }
    let _ = t0;
    t
}

/// Dense FFN: fused gate+up GEMM, SwiGLU, down GEMM.
fn ffn_dense(
    chip: &mut ChipSim,
    group: &TpGroup,
    cfg: &ChipConfig,
    model: &ModelConfig,
    strategy: PartitionStrategy,
    m: u64,
    hbm_layer_bytes: u64,
) {
    let h = model.hidden as u64;
    let inter = model.intermediate as u64;
    let tp = group.len().max(1) as u64;
    let layer_w = model.layer_weight_bytes().max(1);
    let w_gate_up = 2 * h * inter * model.dtype_bytes / tp;
    let w_down = h * inter * model.dtype_bytes / tp;
    let frac = |w: u64| hbm_layer_bytes * w / (layer_w / tp).max(1);
    dist_gemm(chip, group, strategy, m, h, 2 * inter, frac(w_gate_up));
    let t0 = chip.sync(&group.coords);
    let act = compute::swiglu_cycles(&cfg.core, m, ceil_div(inter, tp));
    uniform_op(chip, group, OpClass::Vector, t0, act);
    dist_gemm(chip, group, strategy, m, inter, h, frac(w_down));
}

/// MoE FFN (Qwen3-30B-A3B): router GEMM, token dispatch, per-expert
/// GEMMs, combine. Experts are sharded across the group; dispatch and
/// combine are modeled as activation ring rotations (the all-to-all of a
/// ring-connected group).
fn ffn_moe(
    chip: &mut ChipSim,
    group: &TpGroup,
    cfg: &ChipConfig,
    model: &ModelConfig,
    strategy: PartitionStrategy,
    m: u64,
    hbm_layer_bytes: u64,
) {
    let moe = model.moe.expect("ffn_moe on dense model");
    let h = model.hidden as u64;
    let e_inter = moe.expert_intermediate as u64;
    let tp = group.len().max(1) as u64;
    let dtype = model.dtype_bytes;

    // Router: small replicated GEMM + top-k select.
    let t0 = chip.sync(&group.coords);
    let router = compute::matmul_cycles(cfg, &cfg.core, m, h, moe.n_experts as u64);
    uniform_op(chip, group, OpClass::Gemm, t0, router);
    let t0 = group_now(chip, group);
    let select = compute::vector_cycles(&cfg.core, m * moe.n_experts as u64, 2);
    uniform_op(chip, group, OpClass::Vector, t0, select);

    // Dispatch: each token's activation travels to its experts' cores.
    // On a ring group this is one rotation of the local activation shard.
    let act_shard = m * h * dtype / tp;
    ring_step(chip, group, act_shard, OpClass::P2P);

    // Expert compute: m·top_k (token, expert) pairs spread over the group.
    let pairs_per_core = ceil_div(m * moe.top_k as u64, tp).max(1);
    let expert_w = 3 * h * e_inter * moe.n_experts as u64 * dtype / tp;
    let layer_w = (model.layer_weight_bytes() / tp).max(1);
    let hbm = hbm_layer_bytes * expert_w / layer_w;
    dist_gemm(
        chip,
        group,
        strategy,
        pairs_per_core * tp, // dist_gemm re-shards M internally
        h,
        2 * e_inter,
        hbm / 2,
    );
    let t0 = chip.sync(&group.coords);
    let act = compute::swiglu_cycles(&cfg.core, pairs_per_core, e_inter);
    uniform_op(chip, group, OpClass::Vector, t0, act);
    dist_gemm(chip, group, strategy, pairs_per_core * tp, e_inter, h, hbm / 2);

    // Combine: results rotate back and are weight-summed.
    ring_step(chip, group, act_shard, OpClass::P2P);
    let t0 = group_now(chip, group);
    let sum = compute::vector_cycles(&cfg.core, m * h / tp * moe.top_k as u64, 1);
    uniform_op(chip, group, OpClass::Vector, t0, sum);
}

/// One transformer layer of this group's shard for `batch` (pre-attention
/// norm through the post-FFN residual). Starts from a group sync and ends
/// with a group-uniform op, so the whole group finishes synchronised.
#[allow(clippy::too_many_arguments)]
fn run_layer(
    chip: &mut ChipSim,
    group: &TpGroup,
    cfg: &ChipConfig,
    model: &ModelConfig,
    exec: &ExecConfig,
    batch: &IterBatch,
    kv: &KvCache,
    m: u64,
    hbm_layer: u64,
) {
    let tp = group.len().max(1) as u64;
    let h = model.hidden as u64;
    let dtype = model.dtype_bytes;
    let qd = model.q_dim() as u64;
    let kvd = model.kv_dim() as u64;
    let layer_w = (model.layer_weight_bytes() / tp).max(1);
    let frac = |w_bytes: u64| hbm_layer * w_bytes / layer_w;
    // Phase-aware partition (Fig. 9): every GEMM of this iteration shares
    // the batch's M, so one selection covers the whole layer.
    let strategy = exec.strategy_for(m);

    // Pre-attention RMSNorm.
    let t0 = chip.sync(&group.coords);
    let norm = compute::rmsnorm_cycles(&cfg.core, m, ceil_div(h, tp));
    uniform_op(chip, group, OpClass::Vector, t0, norm);

    // QKV projection.
    let w_qkv = h * (qd + 2 * kvd) * dtype / tp;
    dist_gemm(chip, group, strategy, m, h, qd + 2 * kvd, frac(w_qkv));

    // RoPE on Q and K.
    let t0 = group_now(chip, group);
    let rope = compute::rope_cycles(&cfg.core, m, ceil_div(qd + kvd, tp));
    uniform_op(chip, group, OpClass::Vector, t0, rope);

    // Attention over the KV cache.
    attention_all(chip, group, cfg, model, batch, kv, exec.layers);

    // Output projection + residual.
    let w_o = qd * h * dtype / tp;
    dist_gemm(chip, group, strategy, m, qd, h, frac(w_o));
    let t0 = group_now(chip, group);
    let resid = compute::vector_cycles(&cfg.core, m * ceil_div(h, tp), 1);
    uniform_op(chip, group, OpClass::Vector, t0, resid);

    // Pre-FFN RMSNorm.
    let t0 = group_now(chip, group);
    uniform_op(chip, group, OpClass::Vector, t0, norm);

    // FFN (dense or MoE) + residual.
    if model.moe.is_some() {
        ffn_moe(chip, group, cfg, model, strategy, m, hbm_layer);
    } else {
        ffn_dense(chip, group, cfg, model, strategy, m, hbm_layer);
    }
    let t0 = group_now(chip, group);
    uniform_op(chip, group, OpClass::Vector, t0, resid);
}

/// Output logits (vocab-sharded; embeddings stream from HBM — they are
/// too large to pin and are read once per iteration).
fn run_logits(
    chip: &mut ChipSim,
    group: &TpGroup,
    cfg: &ChipConfig,
    model: &ModelConfig,
    batch: &IterBatch,
) {
    let tp = group.len().max(1) as u64;
    let h = model.hidden as u64;
    let dtype = model.dtype_bytes;
    let lm = batch.logit_tokens();
    let t0 = chip.sync(&group.coords);
    let norm = compute::rmsnorm_cycles(&cfg.core, lm, ceil_div(h, tp));
    uniform_op(chip, group, OpClass::Vector, t0, norm);
    let vocab_shard = ceil_div(model.vocab as u64, tp);
    let embed_bytes = vocab_shard * h * dtype;
    for &c in &group.coords {
        chip.core_mut(c)
            .gemm_hbm_weights(cfg, lm, h, vocab_shard, embed_bytes);
    }
    chip.sync(&group.coords);
}

/// Per-core tracer snapshot over the group (memo delta capture).
fn tracer_snapshot(chip: &ChipSim, group: &TpGroup) -> Vec<Vec<Cycle>> {
    group
        .coords
        .iter()
        .map(|&c| {
            OP_CLASSES
                .iter()
                .map(|&cl| chip.core(c).tracer.cycles(cl))
                .collect()
        })
        .collect()
}

/// Tracer deltas per core since `before`, sparse per op class.
fn tracer_delta(chip: &ChipSim, group: &TpGroup, before: &[Vec<Cycle>]) -> Vec<Vec<(OpClass, Cycle)>> {
    group
        .coords
        .iter()
        .zip(before)
        .map(|(&c, b)| {
            OP_CLASSES
                .iter()
                .enumerate()
                .filter_map(|(i, &cl)| {
                    let d = chip.core(c).tracer.cycles(cl) - b[i];
                    (d > 0).then_some((cl, d))
                })
                .collect()
        })
        .collect()
}

/// Replay a memoized execution `times` times: advance every core by the
/// cached duration and re-record its tracer deltas. Does not touch NoC or
/// HBM state — the memo's documented approximation.
fn replay_entry(chip: &mut ChipSim, group: &TpGroup, entry: &MemoEntry, times: u64) {
    if times == 0 {
        return;
    }
    let t0 = chip.sync(&group.coords);
    for (ci, &c) in group.coords.iter().enumerate() {
        let core = chip.core_mut(c);
        for &(class, cyc) in &entry.trace[ci] {
            core.tracer.record(class, cyc * times);
        }
        core.advance_to(t0 + entry.duration * times);
    }
}

/// Execute one full iteration (all of this group's layers, plus logits on
/// the last stage) for `batch`. Appends the batch's new tokens to `kv`
/// (charging spill writeback) and returns the group's finish cycle.
pub fn run_iteration(
    chip: &mut ChipSim,
    group: &TpGroup,
    model: &ModelConfig,
    plan: &SramPlan,
    exec: &ExecConfig,
    batch: &IterBatch,
    kv: &mut KvCache,
) -> Cycle {
    run_iteration_memo(chip, group, model, plan, exec, batch, kv, None)
}

/// [`run_iteration`] with an optional operator-latency memo: when `memo`
/// is `Some`, one layer is executed in detail per new shape signature and
/// the remaining layers (and later identical iterations) replay the
/// cached duration — see [`crate::model::memo`] for the approximation
/// contract. With `memo == None` the path is bit-identical to the
/// detailed simulator.
#[allow(clippy::too_many_arguments)]
pub fn run_iteration_memo(
    chip: &mut ChipSim,
    group: &TpGroup,
    model: &ModelConfig,
    plan: &SramPlan,
    exec: &ExecConfig,
    batch: &IterBatch,
    kv: &mut KvCache,
    mut memo: Option<&mut LatencyMemo>,
) -> Cycle {
    if batch.is_empty() {
        return group_now(chip, group);
    }
    let cfg = chip.cfg.clone();
    let m = batch.total_q_tokens();

    // Append this iteration's tokens to the KV cache; spilled bytes are
    // written back to HBM (or offloaded over the NoC on SRAM-only chips).
    let mut spill_bytes = 0;
    for item in &batch.items {
        let a = kv.append(item.request, item.q_tokens);
        spill_bytes += a.hbm_bytes;
    }
    if spill_bytes > 0 {
        for &c in &group.coords {
            chip.core_mut(c).hbm_access(spill_bytes, OpClass::KvSpill);
        }
    }

    let hbm_layer = plan.weight_hbm_bytes / exec.layers.max(1) as u64;

    if let Some(memo) = memo.as_deref_mut() {
        // Layers: one detailed execution per new shape, replay the rest.
        let key = LatencyMemo::key_layer(batch, kv);
        if memo.note(key) {
            let entry = memo.peek(key).expect("noted hit");
            replay_entry(chip, group, entry, exec.layers as u64);
        } else {
            let t0 = chip.sync(&group.coords);
            let before = tracer_snapshot(chip, group);
            run_layer(chip, group, &cfg, model, exec, batch, kv, m, hbm_layer);
            let t1 = group_now(chip, group);
            let entry = MemoEntry {
                duration: t1 - t0,
                trace: tracer_delta(chip, group, &before),
            };
            replay_entry(chip, group, &entry, (exec.layers as u64).saturating_sub(1));
            memo.put(key, entry);
        }
        if exec.with_logits {
            let key = LatencyMemo::key_logits(batch);
            if memo.note(key) {
                let entry = memo.peek(key).expect("noted hit");
                replay_entry(chip, group, entry, 1);
            } else {
                let t0 = chip.sync(&group.coords);
                let before = tracer_snapshot(chip, group);
                run_logits(chip, group, &cfg, model, batch);
                let t1 = group_now(chip, group);
                memo.put(
                    key,
                    MemoEntry {
                        duration: t1 - t0,
                        trace: tracer_delta(chip, group, &before),
                    },
                );
            }
        }
        return group_now(chip, group);
    }

    for _layer in 0..exec.layers {
        run_layer(chip, group, &cfg, model, exec, batch, kv, m, hbm_layer);
    }
    if exec.with_logits {
        run_logits(chip, group, &cfg, model, batch);
    }

    group_now(chip, group)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;
    use crate::memmgr::planner::{plan, PlanRequest};
    use crate::model::batch::BatchItem;
    use crate::parallel::placement::Region;

    fn setup(tp: usize) -> (ChipSim, TpGroup) {
        let chip = ChipSim::new(ChipConfig::large_core());
        let group = TpGroup::place(Region::new(0, 0, 2, tp / 2), Placement::Ring);
        (chip, group)
    }

    fn kv_for(model: &ModelConfig, plan_: &SramPlan, layers: usize, tp: usize) -> KvCache {
        let bpt = model.kv_bytes_per_token_layer() * layers as u64 / tp as u64;
        KvCache::new(plan_.kv_bytes, 16, 4 << 30, bpt.max(1), 4096)
    }

    fn run(
        strategy: PartitionStrategy,
        batch: &IterBatch,
        layers: usize,
    ) -> Cycle {
        let (mut chip, group) = setup(4);
        let model = ModelConfig::qwen3_4b();
        let p = plan(
            &chip.cfg.core,
            &model,
            &PlanRequest {
                layers,
                tp: 4,
                iter_tokens: batch.total_q_tokens() as usize,
                kv_share: 0.5,
            },
        );
        let mut kv = kv_for(&model, &p, layers, 4);
        for item in &batch.items {
            kv.admit(item.request);
            if item.kv_tokens > item.q_tokens {
                kv.append(item.request, item.kv_tokens - item.q_tokens);
            }
        }
        // Logits off: they are a layer-count-independent cost that would
        // blur the per-layer comparisons below.
        let exec = ExecConfig::new(strategy, layers, false);
        run_iteration(&mut chip, &group, &model, &p, &exec, batch, &mut kv)
    }

    #[test]
    fn prefill_iteration_completes() {
        let b = IterBatch::new(vec![BatchItem::prefill(1, 256, 256)]);
        let t = run(PartitionStrategy::OneDimK, &b, 2);
        assert!(t > 0);
    }

    #[test]
    fn empty_batch_is_free() {
        let (mut chip, group) = setup(4);
        let model = ModelConfig::qwen3_4b();
        let p = plan(&chip.cfg.core, &model, &PlanRequest::default());
        let mut kv = kv_for(&model, &p, 1, 4);
        let exec = ExecConfig::new(PartitionStrategy::OneDimK, 1, false);
        let t = run_iteration(
            &mut chip,
            &group,
            &model,
            &p,
            &exec,
            &IterBatch::default(),
            &mut kv,
        );
        assert_eq!(t, 0);
    }

    #[test]
    fn short_seq_prefers_allreduce_partition() {
        // Fig. 9's headline: at short sequence length K-partition wins.
        let b = IterBatch::new(vec![BatchItem::prefill(1, 256, 256)]);
        let t_k = run(PartitionStrategy::OneDimK, &b, 2);
        let t_mn = run(PartitionStrategy::OneDimMN, &b, 2);
        assert!(
            t_k < t_mn,
            "K-partition {t_k} should beat MN {t_mn} at seq 256"
        );
    }

    #[test]
    fn long_seq_prefers_allgather_partition() {
        let b = IterBatch::new(vec![BatchItem::prefill(1, 8192, 8192)]);
        let t_k = run(PartitionStrategy::OneDimK, &b, 2);
        let t_mn = run(PartitionStrategy::OneDimMN, &b, 2);
        assert!(
            t_mn < t_k,
            "MN {t_mn} should beat K-partition {t_k} at seq 8192"
        );
    }

    #[test]
    fn decode_iteration_uses_gemv_path() {
        let b = IterBatch::new(vec![BatchItem::decode(1, 512)]);
        let (mut chip, group) = setup(4);
        let model = ModelConfig::qwen3_4b();
        let p = plan(&chip.cfg.core, &model, &PlanRequest::default());
        let mut kv = kv_for(&model, &p, 2, 4);
        kv.admit(1);
        kv.append(1, 511);
        let exec = ExecConfig::new(PartitionStrategy::OneDimK, 2, true);
        run_iteration(&mut chip, &group, &model, &p, &exec, &b, &mut kv);
        let tr = chip.aggregate_tracer();
        assert!(tr.cycles(OpClass::Gemv) > 0, "decode must hit GEMV");
        assert!(tr.cycles(OpClass::Attention) > 0);
    }

    #[test]
    fn longer_context_slows_decode() {
        let mk = |ctx: u64| {
            let b = IterBatch::new(vec![BatchItem::decode(1, ctx)]);
            let (mut chip, group) = setup(4);
            let model = ModelConfig::qwen3_4b();
            let p = plan(&chip.cfg.core, &model, &PlanRequest::default());
            let mut kv = kv_for(&model, &p, 2, 4);
            kv.admit(1);
            kv.append(1, ctx - 1);
            let exec = ExecConfig::new(PartitionStrategy::OneDimK, 2, true);
            run_iteration(&mut chip, &group, &model, &p, &exec, &b, &mut kv)
        };
        assert!(mk(4096) > mk(128));
    }

    #[test]
    fn moe_iteration_runs() {
        let model = ModelConfig::qwen3_30b_a3b();
        let (mut chip, group) = setup(4);
        let p = plan(
            &chip.cfg.core,
            &model,
            &PlanRequest {
                layers: 1,
                tp: 4,
                iter_tokens: 128,
                kv_share: 0.5,
            },
        );
        let mut kv = kv_for(&model, &p, 1, 4);
        kv.admit(1);
        let b = IterBatch::new(vec![BatchItem::prefill(1, 128, 128)]);
        let exec = ExecConfig::new(PartitionStrategy::OneDimK, 1, false);
        let t = run_iteration(&mut chip, &group, &model, &p, &exec, &b, &mut kv);
        assert!(t > 0);
        assert!(chip.aggregate_tracer().cycles(OpClass::P2P) > 0, "MoE dispatch");
    }

    #[test]
    fn kv_spill_charges_hbm() {
        let model = ModelConfig::qwen3_4b();
        let (mut chip, group) = setup(4);
        let p = plan(&chip.cfg.core, &model, &PlanRequest::default());
        // Tiny SRAM KV: everything spills.
        let bpt = model.kv_bytes_per_token_layer() * 2 / 4;
        let mut kv = KvCache::new(0, 16, 4 << 30, bpt, 65536);
        kv.admit(1);
        let b = IterBatch::new(vec![BatchItem::prefill(1, 2048, 2048)]);
        let exec = ExecConfig::new(PartitionStrategy::OneDimK, 2, false);
        run_iteration(&mut chip, &group, &model, &p, &exec, &b, &mut kv);
        assert!(chip.aggregate_tracer().cycles(OpClass::KvSpill) > 0);
    }

    #[test]
    fn two_dim_partition_runs_and_communicates() {
        let b = IterBatch::new(vec![BatchItem::prefill(1, 1024, 1024)]);
        let (mut chip, group) = setup(4);
        let model = ModelConfig::qwen3_4b();
        let p = plan(&chip.cfg.core, &model, &PlanRequest::default());
        let mut kv = kv_for(&model, &p, 1, 4);
        kv.admit(1);
        let exec = ExecConfig::new(PartitionStrategy::TwoDim { rows: 2, cols: 2 }, 1, false);
        let t = run_iteration(&mut chip, &group, &model, &p, &exec, &b, &mut kv);
        assert!(t > 0);
        let tr = chip.aggregate_tracer();
        assert!(tr.cycles(OpClass::AllReduce) > 0);
        assert!(tr.cycles(OpClass::AllGather) > 0);
    }

    #[test]
    fn more_layers_cost_more() {
        let b = IterBatch::new(vec![BatchItem::prefill(1, 512, 512)]);
        let t1 = run(PartitionStrategy::OneDimK, &b, 1);
        let t4 = run(PartitionStrategy::OneDimK, &b, 4);
        assert!(t4 > 3 * t1, "t1={t1} t4={t4}");
    }

    fn decode_run(memo: Option<&mut crate::model::memo::LatencyMemo>) -> Cycle {
        let (mut chip, group) = setup(4);
        let model = ModelConfig::qwen3_4b();
        let p = plan(&chip.cfg.core, &model, &PlanRequest::default());
        let mut kv = kv_for(&model, &p, 4, 4);
        kv.admit(1);
        kv.append(1, 255);
        let exec = ExecConfig::new(PartitionStrategy::OneDimK, 4, true);
        let mut finish = 0;
        let mut memo = memo;
        for step in 0..8u64 {
            let b = IterBatch::new(vec![BatchItem::decode(1, 256 + step)]);
            finish = run_iteration_memo(
                &mut chip,
                &group,
                &model,
                &p,
                &exec,
                &b,
                &mut kv,
                memo.as_deref_mut(),
            );
        }
        finish
    }

    #[test]
    fn phase_switch_selects_by_m() {
        let exec = ExecConfig::new(PartitionStrategy::OneDimMN, 2, false)
            .with_small_m(PartitionStrategy::OneDimK, 512);
        assert_eq!(exec.strategy_for(1), PartitionStrategy::OneDimK);
        assert_eq!(exec.strategy_for(511), PartitionStrategy::OneDimK);
        assert_eq!(exec.strategy_for(512), PartitionStrategy::OneDimMN);
        assert_eq!(exec.strategy_for(8192), PartitionStrategy::OneDimMN);
        // Threshold 0 disables the switch entirely.
        let off = ExecConfig::new(PartitionStrategy::OneDimMN, 2, false)
            .with_small_m(PartitionStrategy::OneDimK, 0);
        assert!(off.small_m.is_none());
        assert_eq!(off.strategy_for(1), PartitionStrategy::OneDimMN);
    }

    #[test]
    fn phase_aware_run_matches_the_static_strategy_it_selects() {
        // A sub-threshold prefill under the switch must land exactly on
        // the K-partition timeline, and a super-threshold one exactly on
        // the MN timeline — the switch changes *which* strategy runs, not
        // how it runs.
        let run_with = |m: u64, exec: ExecConfig| {
            let (mut chip, group) = setup(4);
            let model = ModelConfig::qwen3_4b();
            let p = plan(&chip.cfg.core, &model, &PlanRequest::default());
            let mut kv = kv_for(&model, &p, 2, 4);
            kv.admit(1);
            let b = IterBatch::new(vec![BatchItem::prefill(1, m, m)]);
            run_iteration(&mut chip, &group, &model, &p, &exec, &b, &mut kv)
        };
        let switched = ExecConfig::new(PartitionStrategy::OneDimMN, 2, false)
            .with_small_m(PartitionStrategy::OneDimK, 1024);
        let k = ExecConfig::new(PartitionStrategy::OneDimK, 2, false);
        let mn = ExecConfig::new(PartitionStrategy::OneDimMN, 2, false);
        assert_eq!(run_with(256, switched), run_with(256, k));
        assert_eq!(run_with(2048, switched), run_with(2048, mn));
        assert_ne!(run_with(256, switched), run_with(256, mn));
    }

    #[test]
    fn memoized_decode_hits_and_tracks_detailed_latency() {
        let detailed = decode_run(None);
        let mut memo = crate::model::memo::LatencyMemo::new();
        let memoized = decode_run(Some(&mut memo));
        // 8 decode steps whose KV lengths share one 16-token bucket: one
        // detailed layer + logits, everything else replayed.
        assert!(memo.hits > 0, "no memo hits");
        assert!(memo.hit_rate() > 0.5, "hit rate {}", memo.hit_rate());
        // Contention-free single group: replayed time stays close.
        let (lo, hi) = (detailed as f64 * 0.75, detailed as f64 * 1.25);
        assert!(
            (memoized as f64) > lo && (memoized as f64) < hi,
            "memoized {memoized} vs detailed {detailed}"
        );
    }
}
