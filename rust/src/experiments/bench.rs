//! `bench` — serving bench harness: one reproducible command that measures
//! (1) the prefix-sharing paged-KV win on a shared-prefix / multi-turn
//! conversational trace across all three schedulers, (2) the
//! operator-latency memoization speedup on a fig13-style hardware sweep,
//! (3) the multi-chip cluster grid (router × scheduler on 2 chips, via
//! [`cluster_study::bench_grid`]), (4) the two-tier prefix-cache
//! ablation (SRAM-only vs HBM tier vs +cross-pipe NoC, via
//! [`tier_study::bench_rows`]), (5) the overload control plane
//! (FIFO vs shed/defer under a 2x flash crowd, via
//! [`overload_study::bench_rows`]), (6) the fault-tolerance study
//! (crash recovery vs client resubmission plus degradation windows, via
//! [`fault_study::bench_rows`]), and (7) the fleet-specialization study
//! (planned heterogeneous prefill/decode fleet vs homogeneous fused at
//! equal chip count, via [`fleet_study::bench_rows`]), (8) the
//! two-speed simulation study (transaction-level vs parallel stepping vs
//! the calibrated analytic surrogate on a 16-chip diurnal trace, via
//! [`scale_study::bench_rows`]), and (9) the speculative-decoding study
//! (vanilla decode vs the gamma × acceptance grid with exact token
//! conservation, via [`spec_study::bench_rows`]) — and writes all
//! of it to
//! `BENCH_serving.json` (wall-clock sim time, simulated tokens/s,
//! TTFT/TBT p50/p99, prefix-cache hit rate, memo hit rate,
//! goodput-under-SLO). CI gates this file against `BENCH_baseline.json`
//! with `tools/bench_check`.
//!
//! ```sh
//! cargo run --release -p npusim -- experiment bench
//! ```

use crate::config::{ArrivalProcess, ChipConfig, ModelConfig, PrefixSharing, WorkloadConfig};
use crate::experiments::cluster_study::{self, ClusterRun};
use crate::experiments::fault_study::{self, FaultRun};
use crate::experiments::fleet_study::{self, FleetRun};
use crate::experiments::overload_study::{self, OverloadRun};
use crate::experiments::plan_study::{self, PlanRun};
use crate::experiments::scale_study::{self, ScaleRun};
use crate::experiments::spec_study::{self, SpecRun};
use crate::experiments::tier_study::{self, TierRun};
use crate::experiments::Opts;
use crate::serving::metrics::Metrics;
use crate::serving::pd_disagg::DisaggConfig;
use crate::serving::pd_fusion::{simulate_fusion, FusionConfig};
use crate::serving::request::{self, Request};
use crate::serving::scheduler::{self, HybridConfig, SchedulerConfig};
use crate::sim::chip::ChipSim;
use crate::sim::EventQueue;
use crate::util::table::{f3, Table};
use std::fmt::Write as _;
use std::time::Instant;

/// One measured serving run.
#[derive(Debug, Clone)]
pub struct SystemRun {
    pub system: &'static str,
    pub cache_on: bool,
    pub wall_s: f64,
    pub tok_s: f64,
    pub ttft_mean_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tbt_p50_ms: f64,
    pub tbt_p99_ms: f64,
    pub hit_rate: f64,
    pub tokens_skipped: u64,
    pub kv_mb_deduped: f64,
    pub cow_copies: u64,
    pub evictions: u64,
}

/// The shared-prefix conversational trace of the study.
pub fn shared_trace(opts: &Opts) -> Vec<Request> {
    let mut w = WorkloadConfig::shared_prefix(opts.pick(32, 16));
    if opts.fast {
        // Smaller shared prompt, single turn, one prompt group, arrivals
        // spread by the Poisson process: under in-flight-aware matching a
        // block only hits once its producing prefill completed, so the
        // fast trace needs arrival gaps (not a co-arriving batch) for the
        // cache to demonstrably pay.
        w.prefix = Some(PrefixSharing {
            n_groups: 1,
            shared_prefix_len: 512,
            turns: 1,
            think_time_s: 0.0,
        });
        w.output_len = crate::config::LenDist::Uniform(8, 32);
        w.arrival = ArrivalProcess::Poisson { rate: 4.0 };
    }
    request::generate(&w)
}

/// The three schedulers with prefix caching toggled.
fn with_cache(sys: &SchedulerConfig, on: bool) -> SchedulerConfig {
    match sys {
        SchedulerConfig::Fusion(c) => SchedulerConfig::Fusion(FusionConfig {
            prefix_cache: on,
            ..*c
        }),
        SchedulerConfig::Disagg(c) => SchedulerConfig::Disagg(DisaggConfig {
            prefix_cache: on,
            ..*c
        }),
        SchedulerConfig::Hybrid(c) => SchedulerConfig::Hybrid(HybridConfig {
            fusion: FusionConfig {
                prefix_cache: on,
                ..c.fusion
            },
            ..*c
        }),
    }
}

/// Run one scheduler over `reqs` on a fresh large-core chip, measuring
/// wall-clock. `reqs` must be sorted by arrival.
pub fn run_point(
    model: &ModelConfig,
    reqs: Vec<Request>,
    sys: &SchedulerConfig,
) -> anyhow::Result<(Metrics, f64)> {
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let mut sched = sys.build();
    let t0 = Instant::now();
    let m = scheduler::simulate_requests(&mut chip, model, reqs, sched.as_mut())?;
    Ok((m, t0.elapsed().as_secs_f64()))
}

fn system_run(system: &'static str, cache_on: bool, m: &Metrics, wall_s: f64) -> SystemRun {
    let mut ttft = m.ttft_s();
    let mut tbt = m.tbt_s();
    SystemRun {
        system,
        cache_on,
        wall_s,
        tok_s: m.tokens_per_s(),
        ttft_mean_s: ttft.mean(),
        ttft_p50_s: ttft.median(),
        ttft_p99_s: ttft.p99(),
        tbt_p50_ms: tbt.median() * 1e3,
        tbt_p99_ms: tbt.p99() * 1e3,
        hit_rate: m.cache.prefix_hit_rate(),
        tokens_skipped: m.cache.prefill_tokens_skipped,
        kv_mb_deduped: m.cache.kv_bytes_deduped as f64 / (1 << 20) as f64,
        cow_copies: m.cache.cow_copies,
        evictions: m.cache.prefix_evictions,
    }
}

/// The prefix-sharing study: every scheduler × {cache off, cache on} on
/// the shared-prefix trace `reqs`.
pub fn prefix_study(reqs: &[Request]) -> anyhow::Result<Vec<SystemRun>> {
    let model = ModelConfig::qwen3_4b();
    // Each sweep point replays through one reusable event queue (cleared
    // between points): conversations' turn streams merge into one
    // arrival-ordered list even if the input ever arrives unsorted, at the
    // cost of the clone the replay needs anyway.
    let mut order: EventQueue<usize> = EventQueue::new();
    let systems: [(&'static str, SchedulerConfig); 3] = [
        ("fusion", SchedulerConfig::Fusion(FusionConfig::default())),
        ("disagg", SchedulerConfig::Disagg(DisaggConfig::p42_d21())),
        ("hybrid", SchedulerConfig::Hybrid(HybridConfig::default())),
    ];
    let mut out = Vec::new();
    for (name, sys) in &systems {
        for cache_on in [false, true] {
            order.clear();
            for (i, r) in reqs.iter().enumerate() {
                order.push((r.arrival_s * 1e6) as u64, i);
            }
            let mut replay = Vec::with_capacity(reqs.len());
            while let Some((_, i)) = order.pop() {
                replay.push(reqs[i]);
            }
            let (m, wall) = run_point(&model, replay, &with_cache(sys, cache_on))?;
            out.push(system_run(name, cache_on, &m, wall));
        }
    }
    Ok(out)
}

/// Outcome of the memoization sweep.
#[derive(Debug, Clone, Copy)]
pub struct MemoStudy {
    pub wall_off_s: f64,
    pub wall_on_s: f64,
    pub speedup: f64,
    pub memo_hit_rate: f64,
    pub latency_err_pct: f64,
}

/// One fig13-style cell (PD fusion hardware sweep) with the memo toggled.
fn memo_cell(
    model: &ModelConfig,
    input: usize,
    output: usize,
    n: usize,
    sram_mb: u64,
    stages: usize,
    memo: bool,
) -> anyhow::Result<(f64, Metrics)> {
    let mut chip = ChipSim::new(ChipConfig::small_core().with_sram_mb(sram_mb));
    let w = WorkloadConfig::fixed_ratio(input, output, n);
    let cfg = FusionConfig {
        tp: 4,
        stages,
        memo,
        ..FusionConfig::default()
    };
    let m = simulate_fusion(&mut chip, model, &w, &cfg)?;
    Ok((m.e2e_s().max(), m))
}

/// The fig13-mini sweep, detailed vs memoized.
pub fn memo_study(opts: &Opts) -> anyhow::Result<MemoStudy> {
    let model = ModelConfig::qwen3_8b();
    let output = opts.pick(64, 8);
    let n = opts.pick(8, 2);
    let inputs = opts.pick(vec![512usize, 2048], vec![256]);
    let srams = opts.pick(vec![16u64, 48], vec![16]);
    let stage_counts = opts.pick(vec![12usize, 32], vec![12]);

    let mut wall = [0.0f64; 2];
    let mut latency = [0.0f64; 2];
    let mut hits = 0u64;
    let mut misses = 0u64;
    for (mi, memo) in [false, true].into_iter().enumerate() {
        let t0 = Instant::now();
        for &input in &inputs {
            for &sram in &srams {
                for &stages in &stage_counts {
                    let (e2e, m) = memo_cell(&model, input, output, n, sram, stages, memo)?;
                    latency[mi] += e2e;
                    if memo {
                        hits += m.cache.memo_hits;
                        misses += m.cache.memo_misses;
                    }
                }
            }
        }
        wall[mi] = t0.elapsed().as_secs_f64();
    }
    let err = if latency[0] > 0.0 {
        (latency[1] - latency[0]).abs() / latency[0] * 100.0
    } else {
        0.0
    };
    Ok(MemoStudy {
        wall_off_s: wall[0],
        wall_on_s: wall[1],
        speedup: if wall[1] > 0.0 { wall[0] / wall[1] } else { 0.0 },
        memo_hit_rate: if hits + misses > 0 {
            hits as f64 / (hits + misses) as f64
        } else {
            0.0
        },
        latency_err_pct: err,
    })
}

/// Mean-TTFT reduction of cache-on vs cache-off for `system`, percent.
pub fn ttft_reduction_pct(runs: &[SystemRun], system: &str) -> f64 {
    let off = runs.iter().find(|r| r.system == system && !r.cache_on);
    let on = runs.iter().find(|r| r.system == system && r.cache_on);
    match (off, on) {
        (Some(off), Some(on)) if off.ttft_mean_s > 0.0 => {
            (1.0 - on.ttft_mean_s / off.ttft_mean_s) * 100.0
        }
        _ => 0.0,
    }
}

/// Hand-rolled JSON (no serde in the offline workspace). All strings are
/// static identifiers, so no escaping is needed.
#[allow(clippy::too_many_arguments)]
fn render_json(
    runs: &[SystemRun],
    memo: &MemoStudy,
    shared_fraction: f64,
    cluster: &[ClusterRun],
    tier: &[TierRun],
    plan: &[PlanRun],
    slo: &[OverloadRun],
    fault: &[FaultRun],
    fleet: &[FleetRun],
    scale: &[ScaleRun],
    spec: &[SpecRun],
) -> String {
    let mut j = String::new();
    let _ = writeln!(j, "{{");
    let _ = writeln!(j, "  \"bench\": \"serving\",");
    let _ = writeln!(j, "  \"shared_token_fraction\": {:.4},", shared_fraction);
    let _ = writeln!(
        j,
        "  \"ttft_reduction_pct\": {{\"fusion\": {:.2}, \"disagg\": {:.2}, \"hybrid\": {:.2}}},",
        ttft_reduction_pct(runs, "fusion"),
        ttft_reduction_pct(runs, "disagg"),
        ttft_reduction_pct(runs, "hybrid")
    );
    let _ = writeln!(j, "  \"prefix_cache\": [");
    for (i, r) in runs.iter().enumerate() {
        let _ = writeln!(
            j,
            "    {{\"system\": \"{}\", \"prefix_cache\": {}, \"wall_s\": {:.6}, \
             \"tokens_per_s\": {:.3}, \"ttft_mean_s\": {:.6}, \"ttft_p50_s\": {:.6}, \
             \"ttft_p99_s\": {:.6}, \"tbt_p50_ms\": {:.4}, \"tbt_p99_ms\": {:.4}, \
             \"prefix_hit_rate\": {:.4}, \"prefill_tokens_skipped\": {}, \
             \"kv_mb_deduped\": {:.3}, \"cow_copies\": {}, \"prefix_evictions\": {}}}{}",
            r.system,
            r.cache_on,
            r.wall_s,
            r.tok_s,
            r.ttft_mean_s,
            r.ttft_p50_s,
            r.ttft_p99_s,
            r.tbt_p50_ms,
            r.tbt_p99_ms,
            r.hit_rate,
            r.tokens_skipped,
            r.kv_mb_deduped,
            r.cow_copies,
            r.evictions,
            if i + 1 < runs.len() { "," } else { "" }
        );
    }
    let _ = writeln!(j, "  ],");
    let _ = writeln!(j, "  \"cluster\": [");
    for (i, r) in cluster.iter().enumerate() {
        let _ = writeln!(
            j,
            "    {{\"workload\": \"{}\", \"sched\": \"{}\", \"router\": \"{}\", \
             \"chips\": {}, \"tokens_per_s\": {:.3}, \"ttft_p50_s\": {:.6}, \
             \"ttft_p99_s\": {:.6}, \"tbt_p99_ms\": {:.4}, \"prefix_hit_rate\": {:.4}, \
             \"migrations\": {}, \"icn_mb\": {:.3}}}{}",
            r.workload,
            r.sched,
            r.router,
            r.chips,
            r.tok_s,
            r.ttft_p50_s,
            r.ttft_p99_s,
            r.tbt_p99_ms,
            r.hit_rate,
            r.migrations,
            r.icn_mb,
            if i + 1 < cluster.len() { "," } else { "" }
        );
    }
    let _ = writeln!(j, "  ],");
    let _ = writeln!(j, "  \"tier\": [");
    for (i, r) in tier.iter().enumerate() {
        let _ = writeln!(
            j,
            "    {{\"config\": \"{}\", \"hbm_tier\": {}, \"cross_pipe\": {}, \
             \"tokens_per_s\": {:.3}, \"ttft_p50_s\": {:.6}, \"ttft_p99_s\": {:.6}, \
             \"prefix_hit_rate\": {:.4}, \"prefill_tokens_skipped\": {}, \
             \"tier_demotions\": {}, \"tier_promotions\": {}, \"tier_dropped\": {}, \
             \"prefix_evictions\": {}, \"noc_imports\": {}}}{}",
            r.config,
            r.hbm_tier,
            r.cross_pipe,
            r.tok_s,
            r.ttft_p50_s,
            r.ttft_p99_s,
            r.hit_rate,
            r.tokens_skipped,
            r.demotions,
            r.promotions,
            r.dropped,
            r.evictions,
            r.noc_imports,
            if i + 1 < tier.len() { "," } else { "" }
        );
    }
    let _ = writeln!(j, "  ],");
    let _ = writeln!(j, "  \"plan\": [");
    for (i, r) in plan.iter().enumerate() {
        let _ = writeln!(
            j,
            "    {{\"plan\": \"{}\", \"auto\": {}, \"analytic_score\": {:.1}, \
             \"analytic_rank\": {}, \"sim_makespan_s\": {:.6}, \"sim_rank\": {}, \
             \"tokens_per_s\": {:.3}, \"ttft_p50_s\": {:.6}}}{}",
            r.plan,
            r.auto,
            r.analytic_score,
            r.analytic_rank,
            r.sim_makespan_s,
            r.sim_rank,
            r.tok_s,
            r.ttft_p50_s,
            if i + 1 < plan.len() { "," } else { "" }
        );
    }
    let _ = writeln!(j, "  ],");
    let _ = writeln!(j, "  \"slo\": [");
    for (i, r) in slo.iter().enumerate() {
        let _ = writeln!(
            j,
            "    {{\"policy\": \"{}\", \"offered\": {}, \"completed\": {}, \"shed\": {}, \
             \"deferrals\": {}, \"preemptions\": {}, \"resumes\": {}, \"slo_ttft_s\": {:.6}, \
             \"goodput_tok_s\": {:.3}, \"tokens_per_s\": {:.3}, \"shed_rate\": {:.4}, \
             \"ttft_p99_high_s\": {:.6}, \"ttft_p99_low_s\": {:.6}}}{}",
            r.policy,
            r.offered,
            r.completed,
            r.shed,
            r.deferrals,
            r.preemptions,
            r.resumes,
            r.slo_ttft_s,
            r.goodput_tok_s,
            r.tok_s,
            r.shed_rate,
            r.ttft_p99_high_s,
            r.ttft_p99_low_s,
            if i + 1 < slo.len() { "," } else { "" }
        );
    }
    let _ = writeln!(j, "  ],");
    let _ = writeln!(j, "  \"fault\": [");
    for (i, r) in fault.iter().enumerate() {
        let _ = writeln!(
            j,
            "    {{\"scenario\": \"{}\", \"chips\": {}, \"offered\": {}, \"completed\": {}, \
             \"shed\": {}, \"crashes\": {}, \"restarts\": {}, \"degradations\": {}, \
             \"recovered\": {}, \"retries\": {}, \"recovery_shed\": {}, \
             \"tokens_recomputed\": {}, \"tokens_restored\": {}, \"mean_detect_s\": {:.6}, \
             \"slo_ttft_s\": {:.6}, \"goodput_tok_s\": {:.3}, \"tokens_per_s\": {:.3}}}{}",
            r.scenario,
            r.chips,
            r.offered,
            r.completed,
            r.shed,
            r.crashes,
            r.restarts,
            r.degradations,
            r.recovered,
            r.retries,
            r.recovery_shed,
            r.tokens_recomputed,
            r.tokens_restored,
            r.mean_detect_s,
            r.slo_ttft_s,
            r.goodput_tok_s,
            r.tok_s,
            if i + 1 < fault.len() { "," } else { "" }
        );
    }
    let _ = writeln!(j, "  ],");
    let _ = writeln!(j, "  \"fleet\": [");
    for (i, r) in fleet.iter().enumerate() {
        let _ = writeln!(
            j,
            "    {{\"fleet\": \"{}\", \"chips\": {}, \"n_prefill\": {}, \"n_decode\": {}, \
             \"disaggregated\": {}, \"offered\": {}, \"completed\": {}, \"shed\": {}, \
             \"handoffs\": {}, \"crashes\": {}, \"tokens_exact\": {}, \"icn_mb\": {:.3}, \
             \"slo_ttft_s\": {:.6}, \"goodput_tok_s\": {:.3}, \"tokens_per_s\": {:.3}}}{}",
            r.fleet,
            r.chips,
            r.n_prefill,
            r.n_decode,
            r.disaggregated,
            r.offered,
            r.completed,
            r.shed,
            r.handoffs,
            r.crashes,
            r.tokens_exact,
            r.icn_mb,
            r.slo_ttft_s,
            r.goodput_tok_s,
            r.tok_s,
            if i + 1 < fleet.len() { "," } else { "" }
        );
    }
    let _ = writeln!(j, "  ],");
    let _ = writeln!(j, "  \"scale\": [");
    for (i, r) in scale.iter().enumerate() {
        let _ = writeln!(
            j,
            "    {{\"level\": \"{}\", \"chips\": {}, \"sim_threads\": {}, \"offered\": {}, \
             \"completed\": {}, \"shed\": {}, \"events\": {}, \"wall_s\": {:.6}, \
             \"events_per_s\": {:.3}, \"ttft_ms\": {:.4}, \"tbt_ms\": {:.4}, \
             \"goodput_tok_s\": {:.3}, \"speedup\": {:.3}, \"ttft_err\": {:.4}, \
             \"tbt_err\": {:.4}, \"goodput_err\": {:.4}}}{}",
            r.level,
            r.chips,
            r.sim_threads,
            r.offered,
            r.completed,
            r.shed,
            r.events,
            r.wall_s,
            r.events_per_s,
            r.ttft_ms,
            r.tbt_ms,
            r.goodput_tok_s,
            r.speedup,
            r.ttft_err,
            r.tbt_err,
            r.goodput_err,
            if i + 1 < scale.len() { "," } else { "" }
        );
    }
    let _ = writeln!(j, "  ],");
    let _ = writeln!(j, "  \"spec\": [");
    for (i, r) in spec.iter().enumerate() {
        let _ = writeln!(
            j,
            "    {{\"policy\": \"{}\", \"gamma\": {}, \"acceptance\": {:.4}, \"offered\": {}, \
             \"completed\": {}, \"shed\": {}, \"tokens_exact\": {}, \
             \"acceptance_observed\": {:.4}, \"tbt_p50_ms\": {:.4}, \"tbt_p99_ms\": {:.4}, \
             \"goodput_tok_s\": {:.3}, \"tokens_per_s\": {:.3}, \
             \"tokens_per_weight_stream\": {:.4}, \"verify_steps\": {}, \"verify_m_p50\": {}, \
             \"verify_above_threshold\": {}, \"m_threshold\": {}, \"preemptions\": {}, \
             \"resumes\": {}}}{}",
            r.label,
            r.gamma,
            r.acceptance,
            r.offered,
            r.completed,
            r.shed,
            r.tokens_exact,
            r.acceptance_observed,
            r.tbt_p50_ms,
            r.tbt_p99_ms,
            r.goodput_tok_s,
            r.tok_s,
            r.tokens_per_weight_stream,
            r.verify_steps,
            r.verify_m_p50,
            r.verify_above_threshold,
            r.m_threshold,
            r.preemptions,
            r.resumes,
            if i + 1 < spec.len() { "," } else { "" }
        );
    }
    let _ = writeln!(j, "  ],");
    let _ = writeln!(
        j,
        "  \"memo\": {{\"sweep\": \"fig13-mini\", \"wall_off_s\": {:.6}, \"wall_on_s\": {:.6}, \
         \"speedup\": {:.3}, \"memo_hit_rate\": {:.4}, \"latency_err_pct\": {:.3}}}",
        memo.wall_off_s, memo.wall_on_s, memo.speedup, memo.memo_hit_rate, memo.latency_err_pct
    );
    let _ = writeln!(j, "}}");
    j
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let reqs = shared_trace(opts);
    let shared_fraction = request::shared_token_fraction(&reqs);
    let runs = prefix_study(&reqs)?;
    let memo = memo_study(opts)?;
    let cluster = cluster_study::bench_grid(opts)?;
    let tier = tier_study::bench_rows(opts)?;
    let plan = plan_study::bench_rows(opts)?;
    let slo = overload_study::bench_rows(opts)?;
    let fault = fault_study::bench_rows(opts)?;
    let fleet = fleet_study::bench_rows(opts)?;
    let scale = scale_study::bench_rows(opts)?;
    let spec = spec_study::bench_rows(opts)?;

    let mut t1 = Table::new(
        "bench — prefix-sharing paged KV on the shared-prefix trace (Qwen3-4B, 64 cores)",
        &[
            "system",
            "prefix cache",
            "wall (s)",
            "tok/s",
            "TTFT mean (s)",
            "TTFT p99 (s)",
            "TBT p99 (ms)",
            "hit rate (%)",
            "tokens skipped",
            "KV MB deduped",
        ],
    );
    for r in &runs {
        t1.row(&[
            r.system.to_string(),
            if r.cache_on { "on" } else { "off" }.to_string(),
            f3(r.wall_s),
            f3(r.tok_s),
            f3(r.ttft_mean_s),
            f3(r.ttft_p99_s),
            f3(r.tbt_p99_ms),
            f3(r.hit_rate * 100.0),
            r.tokens_skipped.to_string(),
            f3(r.kv_mb_deduped),
        ]);
    }

    let mut t2 = Table::new(
        "bench — operator-latency memoization (fig13-mini PD-fusion sweep, Qwen3-8B)",
        &[
            "memo",
            "wall (s)",
            "speedup",
            "memo hit rate (%)",
            "latency err (%)",
        ],
    );
    t2.row(&[
        "off".into(),
        f3(memo.wall_off_s),
        "1.000".into(),
        "-".into(),
        "0.000".into(),
    ]);
    t2.row(&[
        "on".into(),
        f3(memo.wall_on_s),
        f3(memo.speedup),
        f3(memo.memo_hit_rate * 100.0),
        f3(memo.latency_err_pct),
    ]);

    let mut t3 = Table::new(
        "bench — 2-chip cluster grid (router × scheduler, prefix cache on)",
        &[
            "workload",
            "sched",
            "router",
            "tok/s",
            "TTFT p50 (s)",
            "TTFT p99 (s)",
            "hit rate (%)",
            "migrations",
        ],
    );
    for r in &cluster {
        t3.row(&[
            r.workload.to_string(),
            r.sched.to_string(),
            r.router.to_string(),
            f3(r.tok_s),
            f3(r.ttft_p50_s),
            f3(r.ttft_p99_s),
            f3(r.hit_rate * 100.0),
            r.migrations.to_string(),
        ]);
    }

    let mut t4 = Table::new(
        "bench — two-tier prefix cache (pressured shared-prefix trace, 16 MB SRAM/core)",
        &[
            "config",
            "tok/s",
            "TTFT p50 (s)",
            "tokens skipped",
            "demote/promote/drop",
            "NoC imports",
        ],
    );
    for r in &tier {
        t4.row(&[
            r.config.to_string(),
            f3(r.tok_s),
            f3(r.ttft_p50_s),
            r.tokens_skipped.to_string(),
            format!("{}/{}/{}", r.demotions, r.promotions, r.dropped),
            r.noc_imports.to_string(),
        ]);
    }

    let mut t5 = Table::new(
        "bench — deployment plans: analytic rank vs simulated (512:48 trace, 64 cores)",
        &[
            "plan",
            "analytic rank",
            "sim rank",
            "sim makespan (s)",
            "tok/s",
            "TTFT p50 (s)",
        ],
    );
    for r in &plan {
        t5.row(&[
            if r.auto { "auto".into() } else { r.plan.clone() },
            r.analytic_rank.to_string(),
            r.sim_rank.to_string(),
            f3(r.sim_makespan_s),
            f3(r.tok_s),
            f3(r.ttft_p50_s),
        ]);
    }

    let mut t6 = Table::new(
        "bench — overload control plane (flash crowd at 2x sustainable rate, 2 chips)",
        &[
            "policy",
            "offered",
            "completed",
            "shed",
            "goodput tok/s (SLO)",
            "TTFT p99 high (s)",
            "TTFT p99 low (s)",
        ],
    );
    for r in &slo {
        t6.row(&[
            r.policy.to_string(),
            r.offered.to_string(),
            r.completed.to_string(),
            format!("{} ({:.0}%)", r.shed, r.shed_rate * 100.0),
            f3(r.goodput_tok_s),
            f3(r.ttft_p99_high_s),
            f3(r.ttft_p99_low_s),
        ]);
    }

    let mut t7 = Table::new(
        "bench — fault tolerance (steady trace at 0.5x fleet capacity, 4 chips)",
        &[
            "scenario",
            "offered",
            "completed",
            "shed",
            "recovered",
            "detect (ms)",
            "goodput tok/s (SLO)",
            "tok/s",
        ],
    );
    for r in &fault {
        t7.row(&[
            r.scenario.to_string(),
            r.offered.to_string(),
            r.completed.to_string(),
            r.shed.to_string(),
            r.recovered.to_string(),
            f3(r.mean_detect_s * 1e3),
            f3(r.goodput_tok_s),
            f3(r.tok_s),
        ]);
    }

    let mut t8 = Table::new(
        "bench — fleet specialization (prefill-heavy trace, 4 chips, planned silicon per role)",
        &[
            "fleet",
            "P/D chips",
            "offered",
            "completed",
            "shed",
            "handoffs",
            "tokens exact",
            "goodput tok/s (SLO)",
            "tok/s",
        ],
    );
    for r in &fleet {
        t8.row(&[
            r.fleet.to_string(),
            format!("{}/{}", r.n_prefill, r.n_decode),
            r.offered.to_string(),
            r.completed.to_string(),
            r.shed.to_string(),
            r.handoffs.to_string(),
            r.tokens_exact.to_string(),
            f3(r.goodput_tok_s),
            f3(r.tok_s),
        ]);
    }

    let mut t9 = Table::new(
        "bench — two-speed simulation (16 chips, diurnal trace, txn vs parallel vs surrogate)",
        &[
            "level",
            "threads",
            "events",
            "wall (s)",
            "events/s",
            "speedup",
            "ttft err",
            "tbt err",
            "goodput err",
        ],
    );
    for r in &scale {
        t9.row(&[
            r.level.to_string(),
            r.sim_threads.to_string(),
            r.events.to_string(),
            f3(r.wall_s),
            f3(r.events_per_s),
            f3(r.speedup),
            f3(r.ttft_err),
            f3(r.tbt_err),
            f3(r.goodput_err),
        ]);
    }

    let mut t10 = Table::new(
        "bench — speculative decoding (vanilla vs gamma × acceptance, Qwen3-4B, 64 cores)",
        &[
            "policy",
            "offered",
            "completed",
            "accept obs",
            "TBT p50 (ms)",
            "goodput tok/s (SLO)",
            "tok/weight-stream",
            "verify M ≥ thresh",
            "tokens exact",
        ],
    );
    for r in &spec {
        t10.row(&[
            r.label.clone(),
            r.offered.to_string(),
            r.completed.to_string(),
            f3(r.acceptance_observed),
            f3(r.tbt_p50_ms),
            f3(r.goodput_tok_s),
            f3(r.tokens_per_weight_stream),
            format!("{}/{}", r.verify_above_threshold, r.verify_steps),
            r.tokens_exact.to_string(),
        ]);
    }

    let cluster_rr = cluster_study::ttft_p50(&cluster, "shared-prefix", "fusion", "rr");
    let cluster_prefix = cluster_study::ttft_p50(&cluster, "shared-prefix", "fusion", "prefix");
    println!(
        "bench: shared tokens {:.1}%  |  fusion TTFT cut {:.1}%  |  memo speedup {:.2}x (hit rate {:.1}%)  |  \
         cluster TTFT p50 rr {:.4}s vs prefix {:.4}s  |  tier skips {} -> {}",
        shared_fraction * 100.0,
        ttft_reduction_pct(&runs, "fusion"),
        memo.speedup,
        memo.memo_hit_rate * 100.0,
        cluster_rr.unwrap_or(0.0),
        cluster_prefix.unwrap_or(0.0),
        tier_study::tokens_skipped(&tier, "sram-only").unwrap_or(0),
        tier_study::tokens_skipped(&tier, "two-tier+noc").unwrap_or(0)
    );

    // BENCH_serving.json: one copy beside the CSVs, one at the repo root
    // (the canonical location the README documents and CI gates on).
    if let Some(dir) = &opts.out_dir {
        let json = render_json(
            &runs,
            &memo,
            shared_fraction,
            &cluster,
            &tier,
            &plan,
            &slo,
            &fault,
            &fleet,
            &scale,
            &spec,
        );
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("BENCH_serving.json"), &json)?;
        std::fs::write("BENCH_serving.json", &json)?;
    }

    Ok(vec![t1, t2, t3, t4, t5, t6, t7, t8, t9, t10])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_trace_is_mostly_shareable_and_deterministic() {
        let opts = Opts::fast();
        let reqs = shared_trace(&opts);
        assert_eq!(reqs.len(), 16);
        assert!(
            request::shared_token_fraction(&reqs) >= 0.5,
            "shared fraction {}",
            request::shared_token_fraction(&reqs)
        );
        assert_eq!(reqs, shared_trace(&opts));
    }

    #[test]
    fn prefix_cache_cuts_ttft_and_lifts_throughput_on_every_scheduler() {
        // The acceptance property (fast-mode scale): ≥30% mean-TTFT cut on
        // the fused schedulers and a measurable throughput gain, with the
        // cache actually hitting and deduplicating bytes.
        let runs = prefix_study(&shared_trace(&Opts::fast())).unwrap();
        assert_eq!(runs.len(), 6);
        for sys in ["fusion", "hybrid"] {
            let cut = ttft_reduction_pct(&runs, sys);
            assert!(cut >= 30.0, "{sys} TTFT cut {cut:.1}% < 30%");
            let off = runs.iter().find(|r| r.system == sys && !r.cache_on).unwrap();
            let on = runs.iter().find(|r| r.system == sys && r.cache_on).unwrap();
            assert!(
                on.tok_s > off.tok_s,
                "{sys} throughput {} !> {}",
                on.tok_s,
                off.tok_s
            );
            assert!(on.hit_rate > 0.0, "{sys} never hit");
            assert!(on.tokens_skipped > 0 && on.kv_mb_deduped > 0.0);
        }
        // Disagg shares through the same machinery; it must at least hit
        // and never lose TTFT.
        let d = ttft_reduction_pct(&runs, "disagg");
        assert!(d >= 0.0, "disagg TTFT regressed: {d:.1}%");
        // Cache-off runs report zero cache activity.
        for r in runs.iter().filter(|r| !r.cache_on) {
            assert_eq!((r.tokens_skipped, r.cow_copies, r.evictions), (0, 0, 0));
            assert_eq!(r.hit_rate, 0.0);
        }
    }

    #[test]
    fn memo_study_hits_and_tracks_latency() {
        let m = memo_study(&Opts::fast()).unwrap();
        assert!(m.memo_hit_rate > 0.3, "hit rate {}", m.memo_hit_rate);
        assert!(m.latency_err_pct.is_finite());
        assert!(m.wall_off_s > 0.0 && m.wall_on_s > 0.0);
    }

    #[test]
    fn json_is_well_formed_enough() {
        let runs = vec![system_run(
            "fusion",
            true,
            &Metrics::new(500.0),
            0.1,
        )];
        let memo = MemoStudy {
            wall_off_s: 1.0,
            wall_on_s: 0.4,
            speedup: 2.5,
            memo_hit_rate: 0.9,
            latency_err_pct: 1.2,
        };
        let cluster = vec![ClusterRun {
            workload: "shared-prefix",
            sched: "fusion",
            router: "prefix",
            chips: 2,
            tok_s: 100.0,
            ttft_p50_s: 0.01,
            ttft_p99_s: 0.05,
            tbt_p99_ms: 12.0,
            hit_rate: 0.8,
            migrations: 3,
            icn_mb: 1.5,
        }];
        let tier = vec![TierRun {
            config: "two-tier+noc",
            hbm_tier: true,
            cross_pipe: true,
            tok_s: 120.0,
            ttft_p50_s: 0.008,
            ttft_p99_s: 0.04,
            hit_rate: 0.9,
            tokens_skipped: 4096,
            demotions: 7,
            promotions: 5,
            dropped: 1,
            evictions: 0,
            noc_imports: 2,
        }];
        let plan = vec![PlanRun {
            plan: "auto".into(),
            auto: true,
            analytic_score: 1.5e8,
            analytic_rank: 1,
            sim_makespan_s: 0.42,
            sim_rank: 1,
            tok_s: 900.0,
            ttft_p50_s: 0.02,
        }];
        let slo = vec![OverloadRun {
            policy: "drop",
            offered: 96,
            completed: 60,
            shed: 36,
            deferrals: 0,
            preemptions: 4,
            resumes: 4,
            slo_ttft_s: 0.05,
            goodput_tok_s: 800.0,
            tok_s: 850.0,
            shed_rate: 0.375,
            ttft_p99_high_s: 0.02,
            ttft_p99_low_s: 0.4,
        }];
        let fault = vec![FaultRun {
            scenario: "crash_recover",
            chips: 4,
            offered: 96,
            completed: 96,
            shed: 0,
            crashes: 1,
            restarts: 0,
            degradations: 0,
            recovered: 3,
            retries: 3,
            recovery_shed: 0,
            tokens_recomputed: 1024,
            tokens_restored: 256,
            mean_detect_s: 0.008,
            slo_ttft_s: 0.05,
            goodput_tok_s: 780.0,
            tok_s: 840.0,
        }];
        let fleet = vec![FleetRun {
            fleet: "fleet-planned",
            chips: 4,
            n_prefill: 2,
            n_decode: 2,
            disaggregated: true,
            offered: 96,
            completed: 96,
            shed: 0,
            handoffs: 96,
            crashes: 0,
            tokens_exact: true,
            slo_ttft_s: 0.1,
            goodput_tok_s: 910.0,
            tok_s: 930.0,
            icn_mb: 48.25,
        }];
        let scale = vec![ScaleRun {
            level: "fast",
            chips: 16,
            sim_threads: 1,
            offered: 512,
            completed: 512,
            shed: 0,
            events: 150_000,
            wall_s: 0.8,
            events_per_s: 187_500.0,
            ttft_ms: 21.5,
            tbt_ms: 9.8,
            goodput_tok_s: 1200.0,
            ttft_err: 0.031,
            tbt_err: 0.012,
            goodput_err: 0.004,
            speedup: 7.2,
        }];
        let spec = vec![SpecRun {
            label: "g4-a0.80".into(),
            gamma: 4,
            acceptance: 0.8,
            offered: 192,
            completed: 192,
            shed: 0,
            expected_decode_tokens: 2112,
            decode_tokens_committed: 2112,
            tokens_exact: true,
            drafted: 2500,
            accepted: 1900,
            rejected: 600,
            acceptance_observed: 0.76,
            tbt_p50_ms: 4.2,
            tbt_p99_ms: 9.1,
            ttft_p99_s: 0.12,
            goodput_tok_s: 1500.0,
            tok_s: 1520.0,
            slo_ttft_s: 0.3,
            slo_tbt_s: 0.02,
            verify_steps: 11,
            verify_m_p50: 512,
            verify_above_threshold: 3,
            m_threshold: 1642,
            tokens_per_weight_stream: 3.4,
            preemptions: 0,
            resumes: 0,
        }];
        let j = render_json(
            &runs, &memo, 0.6, &cluster, &tier, &plan, &slo, &fault, &fleet, &scale, &spec,
        );
        assert!(j.starts_with("{\n"));
        assert!(j.trim_end().ends_with('}'));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!(j.contains("\"memo_hit_rate\": 0.9000"));
        assert!(j.contains("\"system\": \"fusion\""));
        assert!(j.contains("\"router\": \"prefix\""));
        assert!(j.contains("\"chips\": 2"));
        assert!(j.contains("\"config\": \"two-tier+noc\""));
        assert!(j.contains("\"tier_demotions\": 7"));
        assert!(j.contains("\"plan\": \"auto\""));
        assert!(j.contains("\"sim_rank\": 1"));
        assert!(j.contains("\"policy\": \"drop\""));
        assert!(j.contains("\"goodput_tok_s\": 800.000"));
        assert!(j.contains("\"shed_rate\": 0.3750"));
        assert!(j.contains("\"scenario\": \"crash_recover\""));
        assert!(j.contains("\"recovered\": 3"));
        assert!(j.contains("\"mean_detect_s\": 0.008000"));
        assert!(j.contains("\"fleet\": \"fleet-planned\""));
        assert!(j.contains("\"disaggregated\": true"));
        assert!(j.contains("\"handoffs\": 96"));
        assert!(j.contains("\"tokens_exact\": true"));
        assert!(j.contains("\"scale\": ["));
        assert!(j.contains("\"level\": \"fast\""));
        assert!(j.contains("\"sim_threads\": 1"));
        assert!(j.contains("\"speedup\": 7.200"));
        assert!(j.contains("\"ttft_err\": 0.0310"));
        assert!(j.contains("\"spec\": ["));
        assert!(j.contains("\"policy\": \"g4-a0.80\""));
        assert!(j.contains("\"gamma\": 4"));
        assert!(j.contains("\"acceptance_observed\": 0.7600"));
        assert!(j.contains("\"tokens_per_weight_stream\": 3.4000"));
        assert!(j.contains("\"verify_above_threshold\": 3"));
        assert!(j.contains("\"m_threshold\": 1642"));
    }
}
