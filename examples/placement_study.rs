//! Placement study: how tensor-partition and core-placement choices shape
//! single-request latency (a compact §5.4 / Figs. 9–10 walk-through).
//!
//! Run: `cargo run --release --example placement_study`

use npusim::config::{ChipConfig, ModelConfig};
use npusim::experiments::fig10::request_latency_ms;
use npusim::experiments::fig9::prefill_latency_ms;
use npusim::parallel::partition::PartitionStrategy;
use npusim::parallel::placement::Placement;
use npusim::util::table::{f3, Table};

fn main() -> anyhow::Result<()> {
    let model = ModelConfig::qwen3_4b();

    // Partition strategies across sequence lengths (Fig. 9's crossover).
    let mut t = Table::new(
        "partition strategy vs sequence length (Qwen3-4B prefill, TP=4, ms)",
        &["seq", "1d-mn (allgather)", "1d-k (allreduce)", "2d-mnk"],
    );
    for seq in [256u64, 1024, 4096, 16384] {
        t.row(&[
            seq.to_string(),
            f3(prefill_latency_ms(&model, seq, PartitionStrategy::OneDimMN)),
            f3(prefill_latency_ms(&model, seq, PartitionStrategy::OneDimK)),
            f3(prefill_latency_ms(
                &model,
                seq,
                PartitionStrategy::TwoDim { rows: 2, cols: 2 },
            )),
        ]);
    }
    t.print();
    println!();

    // Core placements (Fig. 10): same collective, different physical map.
    let chip = ChipConfig::large_core();
    let mut t = Table::new(
        "core placement (Qwen3-4B, TP=4, seq 2048 + 8 decode steps, ms)",
        &["placement", "latency"],
    );
    for p in Placement::all() {
        t.row(&[
            p.name().to_string(),
            f3(request_latency_ms(&chip, &model, 4, p, 2048, 8)),
        ]);
    }
    t.print();
    println!(
        "\nguidance (§5.6): AllReduce for short/chunked sequences, AllGather or 2-D\n\
         for long prompts; ring placement matches ring collectives best."
    );
    Ok(())
}
