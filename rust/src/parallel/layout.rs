//! Chip layout: carve the mesh into pipeline stages of TP groups (the
//! "divide all NPU cores into multiple pipelines" step of §4.1's
//! core-placement design). Pure mesh geometry — the serving layer builds
//! its pipelines from this, and the auto-planner ([`crate::parallel::plan`])
//! uses it as the fusion-layout feasibility test.

use crate::parallel::placement::{Placement, Region, TpGroup};

/// Factor `tp` into the squarest `(r, c)` grid with `r ≤ rows` and
/// `c ≤ cols` so a TP group occupies a compact rectangle.
pub fn tp_rect(tp: usize, rows: usize, cols: usize) -> (usize, usize) {
    let mut best = (1usize, tp);
    for r in 1..=tp {
        if tp % r != 0 {
            continue;
        }
        let c = tp / r;
        if r <= rows && c <= cols {
            // Prefer the squarest feasible factorization.
            let cur = best.0.abs_diff(best.1);
            if r.abs_diff(c) < cur || best.0 > rows || best.1 > cols {
                best = (r, c);
            }
        }
    }
    best
}

/// Tile the chip into `tp`-core rectangular cells, ordered boustrophedon so
/// consecutive cells (= consecutive pipeline stages) are physically
/// adjacent and inter-stage activation hops stay short.
pub fn carve_stage_cells(rows: usize, cols: usize, tp: usize) -> Vec<Region> {
    let (cr, cc) = tp_rect(tp, rows, cols);
    let grid_rows = rows / cr;
    let grid_cols = cols / cc;
    let mut cells = Vec::with_capacity(grid_rows * grid_cols);
    for gr in 0..grid_rows {
        let cols_iter: Vec<usize> = if gr % 2 == 0 {
            (0..grid_cols).collect()
        } else {
            (0..grid_cols).rev().collect()
        };
        for gc in cols_iter {
            cells.push(Region::new(gr * cr, gc * cc, cr, cc));
        }
    }
    cells
}

/// A full data-parallel layout: `pipelines[p][s]` is the TP group of
/// pipeline `p`'s stage `s`.
#[derive(Debug, Clone)]
pub struct PipelineLayout {
    pub pipelines: Vec<Vec<TpGroup>>,
    pub tp: usize,
    pub stages: usize,
}

impl PipelineLayout {
    /// Build as many `stages`-deep pipelines of TP-`tp` groups as fit on a
    /// `rows × cols` chip. Cells left over stay idle (reported by
    /// [`PipelineLayout::idle_cores`]).
    pub fn build(
        rows: usize,
        cols: usize,
        tp: usize,
        stages: usize,
        placement: Placement,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(tp > 0 && stages > 0, "bad tp/stages");
        let cells = carve_stage_cells(rows, cols, tp);
        anyhow::ensure!(
            cells.len() >= stages,
            "chip has {} cells of {tp} cores; cannot fit {stages} stages",
            cells.len()
        );
        let n_pipelines = cells.len() / stages;
        let mut pipelines = Vec::with_capacity(n_pipelines);
        for p in 0..n_pipelines {
            let mut stage_groups = Vec::with_capacity(stages);
            for s in 0..stages {
                stage_groups.push(TpGroup::place(cells[p * stages + s], placement));
            }
            pipelines.push(stage_groups);
        }
        Ok(PipelineLayout {
            pipelines,
            tp,
            stages,
        })
    }

    /// Build the fused-pipeline layout a [`crate::parallel::plan::DeploymentPlan`]
    /// describes on a `rows × cols` chip.
    pub fn from_plan(
        rows: usize,
        cols: usize,
        plan: &crate::parallel::plan::DeploymentPlan,
    ) -> anyhow::Result<Self> {
        Self::build(rows, cols, plan.tp, plan.stages, plan.placement)
    }

    pub fn n_pipelines(&self) -> usize {
        self.pipelines.len()
    }

    /// Cores used by the layout.
    pub fn used_cores(&self) -> usize {
        self.n_pipelines() * self.stages * self.tp
    }

    /// Cores left idle on a `rows × cols` chip.
    pub fn idle_cores(&self, rows: usize, cols: usize) -> usize {
        rows * cols - self.used_cores()
    }

    /// Layer counts per stage for a `layers`-layer model (earlier stages
    /// take the remainder).
    pub fn layers_per_stage(&self, layers: usize) -> Vec<usize> {
        let base = layers / self.stages;
        let extra = layers % self.stages;
        (0..self.stages)
            .map(|s| base + usize::from(s < extra))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn tp_rect_prefers_square() {
        assert_eq!(tp_rect(4, 8, 8), (2, 2));
        assert_eq!(tp_rect(16, 8, 8), (4, 4));
        assert_eq!(tp_rect(8, 8, 8), (2, 4));
        assert_eq!(tp_rect(2, 8, 8), (1, 2));
    }

    #[test]
    fn cells_tile_the_chip_disjointly() {
        let cells = carve_stage_cells(8, 8, 4);
        assert_eq!(cells.len(), 16);
        let mut seen = HashSet::new();
        for cell in &cells {
            for c in cell.coords() {
                assert!(seen.insert(c), "overlap at {c:?}");
            }
        }
        assert_eq!(seen.len(), 64);
    }

    #[test]
    fn boustrophedon_cells_are_adjacent() {
        let cells = carve_stage_cells(8, 8, 4);
        for pair in cells.windows(2) {
            let (a, b) = (pair[0], pair[1]);
            // Adjacent cells share a border: center distance == cell size.
            let dr = a.row0.abs_diff(b.row0);
            let dc = a.col0.abs_diff(b.col0);
            assert!(dr + dc == 2, "cells {a:?} -> {b:?} not adjacent");
        }
    }

    #[test]
    fn fig13_layouts_fit() {
        // 256 cores, TP=4: 64 cells; stages 12/18/32 -> 5/3/2 pipelines.
        for (stages, pipes) in [(12usize, 5usize), (18, 3), (32, 2)] {
            let l = PipelineLayout::build(16, 16, 4, stages, Placement::Ring).unwrap();
            assert_eq!(l.n_pipelines(), pipes, "stages={stages}");
            assert!(l.idle_cores(16, 16) < 16 * 16);
        }
    }

    #[test]
    fn layers_split_evenly() {
        let l = PipelineLayout::build(8, 8, 4, 3, Placement::Ring).unwrap();
        assert_eq!(l.layers_per_stage(36), vec![12, 12, 12]);
        assert_eq!(l.layers_per_stage(37), vec![13, 12, 12]);
        assert_eq!(
            l.layers_per_stage(36).iter().sum::<usize>(),
            36
        );
    }

    #[test]
    fn too_many_stages_rejected() {
        assert!(PipelineLayout::build(4, 4, 4, 5, Placement::Ring).is_err());
    }

    #[test]
    fn from_plan_matches_explicit_build() {
        let plan = crate::parallel::plan::DeploymentPlan::fusion_default();
        let a = PipelineLayout::from_plan(8, 8, &plan).unwrap();
        let b = PipelineLayout::build(8, 8, plan.tp, plan.stages, plan.placement).unwrap();
        assert_eq!(a.n_pipelines(), b.n_pipelines());
        assert_eq!(a.pipelines[0][0].coords, b.pipelines[0][0].coords);
    }
}
