//! PD fusion behind the [`Scheduler`] trait: every pipeline co-locates
//! chunked prefill and decode under a per-iteration token budget
//! (§4.3.2). The policy logic lives in [`super::pipe`]; this type owns the
//! pipeline set, request-to-pipe assignment, and earliest-actionable-pipe
//! selection.
//!
//! Request assignment is static round-robin by default. With
//! `FusionConfig::cross_pipe` (and the prefix cache on) it becomes
//! **cache-affinity-aware**: [`Scheduler::enqueue`] scores pipes by probed
//! tier-weighted prefix overlap against load (`pipe::route_request`) and,
//! when the holding pipe is overloaded, imports the matched KV to a
//! lighter pipe over the on-chip NoC (`pipe::stream_prefix_over_noc`) —
//! charged and delayed-landing, deduplicated against imports already in
//! flight — instead of recomputing the prefill.

use super::pipe::{self, Pipe};
use super::Scheduler;
use crate::config::ModelConfig;
use crate::memmgr::prefix::{keys_prefix, BlockKey, TierMatch};
use crate::memmgr::KV_BLOCK_TOKENS;
use crate::serving::metrics::{CacheStats, Metrics};
use crate::serving::pd_fusion::FusionConfig;
use crate::serving::request::Request;
use crate::sim::chip::ChipSim;
use crate::util::units::{cycles_to_secs, secs_to_cycles, Cycle};

/// The cross-pipe affinity bookkeeping shared by the fusion and hybrid
/// schedulers: NoC-import sizing, in-flight-transfer dedup, deferred
/// arrivals to restore, and the import counters — one struct so the two
/// policies cannot drift.
#[derive(Debug, Default)]
pub(crate) struct AffinityState {
    /// Whole-model KV bytes per token (NoC import sizing), set by
    /// [`Scheduler::prepare`].
    kv_bytes_per_token: u64,
    /// `(request id, true arrival cycle)` of NoC-imported requests whose
    /// admission was deferred to the KV landing; their recorded arrivals
    /// are restored after completion so TTFT charges the transfer wait.
    rebase: Vec<(u64, Cycle)>,
    /// In-flight imports as `(first matched key, dst pipe, landing)`:
    /// co-arriving requests sharing one prefix piggyback on the transfer
    /// already in the air instead of paying a duplicate copy of the same
    /// bytes (the pipe-level twin of the cluster driver's transit dedup).
    inflight: Vec<(BlockKey, usize, Cycle)>,
    noc_imports: u64,
    noc_import_tokens: u64,
}

impl AffinityState {
    /// Reset for a fresh [`Scheduler::prepare`].
    pub(crate) fn reset(&mut self, kv_bytes_per_token: u64) {
        self.kv_bytes_per_token = kv_bytes_per_token;
        self.rebase.clear();
        self.inflight.clear();
        self.noc_imports = 0;
        self.noc_import_tokens = 0;
    }

    /// Cross-pipe prefix imports performed so far (observability).
    pub(crate) fn noc_imports(&self) -> u64 {
        self.noc_imports
    }

    /// Shared fusion/hybrid enqueue: static round-robin via `next_pipe`,
    /// or — with `cross_pipe` on a multi-pipe layout — cache-affinity
    /// routing with a charged, delayed-landing NoC import off overloaded
    /// holders (deduplicated against imports already in flight).
    pub(crate) fn enqueue(
        &mut self,
        chip: &mut ChipSim,
        pipes: &mut [Pipe],
        cfg: &FusionConfig,
        next_pipe: &mut usize,
        req: Request,
    ) {
        let n = pipes.len();
        if !(cfg.prefix_cache && cfg.cross_pipe && n > 1) {
            pipes[*next_pipe % n].queue.push_back(req);
            *next_pipe = (*next_pipe + 1) % n;
            return;
        }
        let freq = chip.cfg.freq_mhz;
        let at = secs_to_cycles(req.arrival_s, freq);
        // Landed imports are visible to the probes from here on; only the
        // still-in-transit ones are piggyback targets.
        self.inflight.retain(|&(_, _, landing)| landing > at);
        let keys = req.block_keys(KV_BLOCK_TOKENS);
        let limit = (req.input_len as u64).saturating_sub(1);
        let route = pipe::route_request(pipes, &keys, limit, at, cfg.affinity_gap);
        match route.import_from {
            Some(src) if src != route.pipe && route.match_tokens > 0 => {
                // An import of this prefix may already be in the air
                // (co-arriving turns of one conversation while the holder
                // stays overloaded): ride it instead of paying a
                // duplicate transfer of the same bytes.
                let dup = keys.first().and_then(|k0| {
                    self.inflight
                        .iter()
                        .find(|e| e.0 == *k0)
                        .map(|e| (e.1, e.2))
                });
                let (dst, landing) = match dup {
                    Some(hit) => hit,
                    None => {
                        let landing = pipe::stream_prefix_over_noc(
                            chip,
                            pipes,
                            src,
                            route.pipe,
                            route.match_tokens,
                            self.kv_bytes_per_token,
                            at,
                        );
                        self.noc_imports += 1;
                        self.noc_import_tokens += route.match_tokens;
                        if let Some(&k0) = keys.first() {
                            self.inflight.push((k0, route.pipe, landing));
                        }
                        (route.pipe, landing)
                    }
                };
                // Defer the admission to the landing instant so the
                // request actually matches the imported copy; the true
                // arrival is restored in the metrics after completion.
                // Seeding readiness is derived from the (seconds-rounded)
                // deferred arrival so the float round-trip can never land
                // the admission one cycle before the seed — the same
                // guard the cluster driver applies to its transits.
                let id = req.id;
                let mut req = req;
                req.arrival_s = req.arrival_s.max(cycles_to_secs(landing, freq));
                if dup.is_none() {
                    let ready = secs_to_cycles(req.arrival_s, freq).min(landing);
                    pipes[dst].seed_prefix(&keys_prefix(&keys, route.match_tokens), ready);
                }
                pipes[dst].queue.push_back(req);
                self.rebase.push((id, at));
            }
            _ => {
                pipes[route.pipe].queue.push_back(req);
            }
        }
    }

    /// Restore the true arrivals of completed NoC-imported requests
    /// (their enqueue-time arrival was bumped to the KV landing). Entries
    /// whose request has not completed yet stay pending.
    pub(crate) fn on_completions(&mut self, metrics: &mut Metrics) {
        if !self.rebase.is_empty() {
            self.rebase
                .retain(|&(id, arrival)| !metrics.rebase_arrival(id, arrival));
        }
    }

    /// Fold the import counters into a run's cache stats.
    pub(crate) fn collect(&self, out: &mut CacheStats) {
        out.noc_prefix_imports += self.noc_imports;
        out.noc_prefix_tokens += self.noc_import_tokens;
    }
}

/// The fused scheduler: N identical pipelines, requests assigned by
/// round-robin (or cache affinity with `cross_pipe`), decode-first budget
/// batching within each.
pub struct FusionScheduler {
    cfg: FusionConfig,
    pipes: Vec<Pipe>,
    /// Round-robin cursor: the pipe the next [`Scheduler::enqueue`]
    /// targets while affinity routing is off.
    next_pipe: usize,
    affinity: AffinityState,
}

impl FusionScheduler {
    /// Build an (un-prepared) scheduler for `cfg`.
    pub fn new(cfg: FusionConfig) -> Self {
        FusionScheduler {
            cfg,
            pipes: Vec::new(),
            next_pipe: 0,
            affinity: AffinityState::default(),
        }
    }

    /// Number of data-parallel pipelines after `init`.
    pub fn n_pipelines(&self) -> usize {
        self.pipes.len()
    }

    /// Cross-pipe prefix imports performed so far (observability).
    pub fn noc_imports(&self) -> u64 {
        self.affinity.noc_imports()
    }
}

impl Scheduler for FusionScheduler {
    fn name(&self) -> &'static str {
        "fusion"
    }

    fn prepare(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        max_tokens: usize,
    ) -> anyhow::Result<()> {
        self.pipes = pipe::build_pipes(chip, model, &self.cfg, max_tokens.max(1))?;
        self.next_pipe = 0;
        self.affinity.reset(model.kv_bytes_per_token());
        Ok(())
    }

    fn enqueue(&mut self, chip: &mut ChipSim, req: Request) {
        self.affinity
            .enqueue(chip, &mut self.pipes, &self.cfg, &mut self.next_pipe, req);
    }

    fn step(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        metrics: &mut Metrics,
    ) -> anyhow::Result<usize> {
        let freq = chip.cfg.freq_mhz;
        // Pick the pipeline with the earliest actionable work.
        let (pi, t) = self
            .pipes
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.next_action(chip, freq).map(|t| (i, t)))
            .min_by_key(|&(_, t)| t)
            .ok_or_else(|| anyhow::anyhow!("fusion deadlock: no actionable pipeline"))?;
        let mut no_handoffs = Vec::new();
        let completions = self.pipes[pi].tick(
            chip,
            model,
            &self.cfg,
            t,
            metrics,
            freq,
            false,
            &mut no_handoffs,
        );
        if completions > 0 {
            self.affinity.on_completions(metrics);
        }
        Ok(completions)
    }

    fn next_action(&self, chip: &ChipSim) -> Option<Cycle> {
        pipe::earliest_action(&self.pipes, chip)
    }

    fn pending_work(&self) -> usize {
        pipe::total_pending(&self.pipes)
    }

    fn kv_utilization(&self) -> f64 {
        pipe::mean_kv_utilization(&self.pipes)
    }

    fn backpressure(&self) -> f64 {
        pipe::backpressure(&self.pipes, self.cfg.max_batch)
    }

    fn probe_prefix(&self, keys: &[BlockKey], limit: u64, at: Cycle) -> u64 {
        pipe::best_prefix_match(&self.pipes, keys, limit, at)
    }

    fn probe_prefix_tiered(&self, keys: &[BlockKey], limit: u64, at: Cycle) -> TierMatch {
        pipe::best_prefix_match_tiered(&self.pipes, keys, limit, at)
    }

    fn import_prefix(&mut self, keys: &[BlockKey], ready_at: Cycle) {
        pipe::seed_all(&mut self.pipes, keys, ready_at);
    }

    fn drain_incomplete(&mut self) -> Vec<super::Incomplete> {
        let mut out: Vec<super::Incomplete> = self
            .pipes
            .iter_mut()
            .flat_map(|p| p.drain_incomplete())
            .collect();
        out.sort_by_key(|i| i.req.id);
        out
    }

    fn collect_cache_stats(&self, out: &mut crate::serving::metrics::CacheStats) {
        for p in &self.pipes {
            p.collect_cache_stats(out);
        }
        self.affinity.collect(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChipConfig, PrefixSharing, WorkloadConfig};
    use crate::serving::request;
    use crate::serving::scheduler::simulate;

    #[test]
    fn small_max_batch_does_not_starve_requests() {
        // Admission back-pressure (max_batch 2, 10 requests): every request
        // must still retire exactly once — queued requests are admitted as
        // earlier ones release their KV.
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(128, 8, 10);
        let cfg = FusionConfig {
            max_batch: 2,
            ..FusionConfig::default()
        };
        let mut sched = FusionScheduler::new(cfg);
        let m = simulate(&mut chip, &model, &w, &mut sched).unwrap();
        assert_eq!(m.n_requests(), 10);
        let mut ids: Vec<u64> = m.records().iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn layout_reported_after_init() {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        let mut sched = FusionScheduler::new(FusionConfig::default());
        sched
            .init(&mut chip, &model, Vec::new())
            .expect("layout fits");
        // 8x8 chip, TP=4 (2x2 cells), 4 stages -> 4 data-parallel pipes.
        assert_eq!(sched.n_pipelines(), 4);
    }

    /// A shared-prefix trace whose conversation turns are spread by think
    /// time, so turn N's prefix is cached-and-ready when turn N+1 arrives.
    fn turny_workload(n: usize) -> WorkloadConfig {
        WorkloadConfig::shared_prefix(n)
            .with_seed(29)
            .with_prefix(PrefixSharing {
                n_groups: n / 2,
                shared_prefix_len: 512,
                turns: 2,
                think_time_s: 1.5,
            })
    }

    #[test]
    fn cross_pipe_affinity_lifts_prefill_tokens_skipped() {
        // Round-robin admission scatters conversation turns across pipes,
        // so a turn often lands off the pipe caching its context; affinity
        // routing (or the NoC import) recovers those hits. Affinity needs
        // admission-time cache state, so this runs through the streamed
        // one-chip cluster driver (batch init enqueues against cold
        // caches, where affinity degrades to least-loaded by design).
        use crate::serving::cluster::{self, ClusterConfig, RouterPolicy};
        let model = ModelConfig::qwen3_4b();
        let reqs = request::generate(&turny_workload(12));
        let base = FusionConfig {
            prefix_cache: true,
            ..FusionConfig::default()
        };
        let run = |cfg: FusionConfig| {
            let ccfg = ClusterConfig::new(
                ChipConfig::large_core(),
                1,
                crate::serving::scheduler::SchedulerConfig::Fusion(cfg),
                RouterPolicy::RoundRobin,
            );
            cluster::simulate_cluster_requests(&ccfg, &model, reqs.clone())
                .unwrap()
                .aggregate()
        };
        let m_rr = run(base);
        let m_aff = run(FusionConfig {
            cross_pipe: true,
            hbm_tier: true,
            ..base
        });
        assert_eq!(m_aff.n_requests(), m_rr.n_requests());
        assert!(
            m_aff.cache.prefill_tokens_skipped > m_rr.cache.prefill_tokens_skipped,
            "affinity {} !> round-robin {}",
            m_aff.cache.prefill_tokens_skipped,
            m_rr.cache.prefill_tokens_skipped
        );
        for r in m_aff.records() {
            assert!(r.first_token >= r.arrival, "{r:?}");
            assert!(r.finish >= r.first_token, "{r:?}");
        }
    }

    #[test]
    fn cross_pipe_off_keeps_round_robin_assignment() {
        // The golden guard at the policy level: with the new flags off,
        // enqueue still round-robins — pipe queues receive exactly the
        // interleaved request sequence.
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        let mut sched = FusionScheduler::new(FusionConfig::default());
        let reqs = request::generate(&WorkloadConfig::fixed_ratio(64, 4, 8));
        sched.prepare(&mut chip, &model, 128).unwrap();
        for r in reqs {
            sched.enqueue(&mut chip, r);
        }
        for (i, p) in sched.pipes.iter().enumerate() {
            let ids: Vec<u64> = p.queue.iter().map(|r| r.id).collect();
            assert_eq!(ids, vec![i as u64, i as u64 + 4], "pipe {i}");
        }
    }
}
