//! `cargo bench` target for the design-choice ablations (chunk size, KV
//! block granularity, planner split, PD placement policy).

use npusim::experiments::{self, Opts};
use npusim::util::bench::Bench;

fn main() {
    let bench = Bench::new("ablations").iters(1).warmup(0);
    bench.run("ablations", || {
        experiments::run("ablations", &Opts::default()).expect("experiment failed");
    });
}
