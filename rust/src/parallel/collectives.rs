//! Ring collective schedules executed on the simulated mesh.
//!
//! Collectives are expressed as rounds of simultaneous neighbour transfers
//! over the [`TpGroup`]'s logical ring; physical hop counts and link
//! contention come out of the NoC model, which is exactly how placement
//! quality (Fig. 10) manifests: a 2-hop logical neighbour locks two links
//! per transfer, halving effective ring bandwidth.

use super::placement::TpGroup;
use crate::sim::chip::ChipSim;
use crate::sim::compute;
use crate::sim::tracer::OpClass;
use crate::util::units::Cycle;

/// One ring rotation step: every rank sends `bytes` to its ring successor
/// simultaneously; clocks of all ranks synchronise at the step barrier
/// (ranks cannot start the next rotation before their predecessor's data
/// arrives). Returns the barrier cycle.
pub fn ring_step(chip: &mut ChipSim, group: &TpGroup, bytes: u64, class: OpClass) -> Cycle {
    let n = group.len();
    if n <= 1 || bytes == 0 {
        return chip.sync(&group.coords);
    }
    // Issue all sends at each sender's current clock; deterministic order.
    let mut finishes = Vec::with_capacity(n);
    for i in 0..n {
        let src = group.coords[i];
        let dst = group.coords[(i + 1) % n];
        let depart = chip.core(src).now();
        let t = chip.mesh.transfer(src, dst, bytes, depart);
        chip.core_mut(src).tracer.record(class, t.finish - depart);
        finishes.push(t.finish);
    }
    // Each rank may proceed once it has sent and received; ring steps are
    // lock-step across the group, so synchronise on the slowest transfer.
    let barrier = finishes.into_iter().max().unwrap();
    for &c in &group.coords {
        chip.core_mut(c).advance_to(barrier);
    }
    barrier
}

/// Ring AllGather: every rank ends up with all `n` shards of `shard_bytes`.
/// `n-1` rotation steps, each moving one shard per rank.
pub fn ring_all_gather(chip: &mut ChipSim, group: &TpGroup, shard_bytes: u64) -> Cycle {
    let n = group.len();
    if n <= 1 {
        return chip.sync(&group.coords);
    }
    let mut t = 0;
    for _ in 0..n - 1 {
        t = ring_step(chip, group, shard_bytes, OpClass::AllGather);
    }
    t
}

/// Ring AllReduce over `data_bytes` per rank: reduce-scatter (`n-1` steps of
/// `data_bytes/n` + elementwise add) followed by allgather (`n-1` steps).
pub fn ring_all_reduce(chip: &mut ChipSim, group: &TpGroup, data_bytes: u64) -> Cycle {
    let n = group.len();
    if n <= 1 {
        return chip.sync(&group.coords);
    }
    let chunk = (data_bytes as usize).div_ceil(n) as u64;
    let elems = chunk / chip.cfg.dtype_bytes.max(1);
    let mut t = 0;
    // Reduce-scatter: each step transfers a chunk and reduces it.
    for _ in 0..n - 1 {
        ring_step(chip, group, chunk, OpClass::AllReduce);
        // Elementwise accumulate on every rank (vector unit).
        for &c in &group.coords {
            let core = chip.core_mut(c);
            let add = compute::vector_cycles(&core.cfg, elems, 1);
            core.tracer.record(OpClass::Vector, add);
            core.advance_to(core.now() + add);
        }
        t = chip.sync(&group.coords);
    }
    // AllGather phase.
    for _ in 0..n - 1 {
        t = ring_step(chip, group, chunk, OpClass::AllReduce);
    }
    t
}

/// AllReduce along one row/column sub-ring of a 2-D grid (used by the 2-D
/// partition's per-iteration row reduction).
pub fn sub_ring_all_reduce(chip: &mut ChipSim, ring: &[crate::sim::noc::Coord], data_bytes: u64) -> Cycle {
    let group = TpGroup {
        coords: ring.to_vec(),
        placement: super::placement::Placement::Ring,
    };
    ring_all_reduce(chip, &group, data_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;
    use crate::parallel::placement::{Placement, Region};

    fn chip() -> ChipSim {
        ChipSim::new(ChipConfig::large_core())
    }

    fn group(placement: Placement, w: usize) -> TpGroup {
        TpGroup::place(Region::new(0, 0, 2, w / 2), placement)
    }

    #[test]
    fn ring_step_advances_all_cores_equally() {
        let mut c = chip();
        let g = group(Placement::Ring, 4);
        let t = ring_step(&mut c, &g, 25_600, OpClass::AllGather);
        assert!(t > 0);
        for &co in &g.coords {
            assert_eq!(c.core(co).now(), t);
        }
    }

    #[test]
    fn all_gather_scales_with_group_size() {
        let mut c = chip();
        let g2 = TpGroup::place(Region::new(0, 0, 2, 1), Placement::Ring);
        let t2 = ring_all_gather(&mut c, &g2, 10_000);
        let mut c = chip();
        let g8 = TpGroup::place(Region::new(0, 0, 2, 4), Placement::Ring);
        let t8 = ring_all_gather(&mut c, &g8, 10_000);
        // 7 steps vs 1 step.
        assert!(t8 > 5 * t2, "t2={t2} t8={t8}");
    }

    #[test]
    fn all_reduce_moves_two_passes_of_data() {
        let mut c1 = chip();
        let g = TpGroup::place(Region::new(0, 0, 2, 2), Placement::Ring);
        let tg = ring_all_gather(&mut c1, &g, 100_000 / 4);
        let mut c2 = chip();
        let g = TpGroup::place(Region::new(0, 0, 2, 2), Placement::Ring);
        let tr = ring_all_reduce(&mut c2, &g, 100_000);
        // AllReduce ≈ 2× the steps of AllGather on the same total bytes.
        assert!(tr > tg, "tr={tr} tg={tg}");
        assert!(tr < 4 * tg.max(1), "tr={tr} tg={tg}");
    }

    #[test]
    fn one_hop_ring_beats_linear_seq() {
        // Same logical collective, different placement: linear-seq has a
        // long wrap hop that serialises against the forward traffic.
        let mut c1 = chip();
        let ring = TpGroup::place(Region::new(0, 0, 2, 8), Placement::Ring);
        let t_ring = ring_all_gather(&mut c1, &ring, 1 << 20);
        let mut c2 = chip();
        let lin = TpGroup::place(Region::new(0, 0, 2, 8), Placement::LinearSeq);
        let t_lin = ring_all_gather(&mut c2, &lin, 1 << 20);
        assert!(
            t_ring < t_lin,
            "ring {t_ring} should beat linear-seq {t_lin}"
        );
    }

    #[test]
    fn singleton_group_is_free() {
        let mut c = chip();
        let g = TpGroup::place(Region::new(0, 0, 1, 1), Placement::Ring);
        assert_eq!(ring_all_gather(&mut c, &g, 1 << 20), 0);
        assert_eq!(ring_all_reduce(&mut c, &g, 1 << 20), 0);
    }

    #[test]
    fn zero_bytes_step_syncs_only() {
        let mut c = chip();
        let g = group(Placement::Ring, 4);
        c.core_mut(g.coords[0]).advance_to(777);
        let t = ring_step(&mut c, &g, 0, OpClass::AllGather);
        assert_eq!(t, 777);
    }
}
