//! A placed worker: one TP group with its execution config, SRAM plan and
//! KV cache — the unit both PD-fusion pipelines and PD-disaggregation
//! prefill/decode groups are assembled from.

use crate::config::{ChipConfig, CoreConfig, ModelConfig};
use crate::memmgr::planner::{plan, PlanRequest};
use crate::memmgr::prefix::{BlockKey, TierMatch};
use crate::memmgr::{KvCache, KV_BLOCK_TOKENS};
use crate::model::exec::{group_now, run_iteration_memo, ExecConfig};
use crate::model::memo::{LatencyMemo, SimLevel, Surrogate, SurrogateShape};
use crate::model::IterBatch;
use crate::parallel::placement::TpGroup;
use crate::sim::chip::ChipSim;
use crate::sim::tracer::OpClass;
use crate::util::units::Cycle;

pub use crate::parallel::plan::DEFAULT_HBM_TIER_FRAC;

/// One TP group ready to execute iterations.
#[derive(Debug)]
pub struct StageWorker {
    pub group: TpGroup,
    pub exec: ExecConfig,
    pub plan: crate::memmgr::SramPlan,
    pub kv: KvCache,
    /// Operator-latency memo (None = fully detailed simulation).
    pub memo: Option<LatencyMemo>,
    /// Calibrated analytic surrogate (`--sim-level fast`; None = the
    /// transaction-level path, bit-identical to the historical simulator).
    pub surrogate: Option<Surrogate>,
}

impl StageWorker {
    /// Build a worker executing `exec` (strategy + phase switch + stage
    /// layer range + logits flag) on `group`.
    ///
    /// * `core`: the hardware resources of this group's cores (decode
    ///   workers pass the heterogeneous decode-core config).
    /// * `iter_tokens`: planning token budget per iteration.
    /// * `kv_share`: SRAM remainder split (see [`PlanRequest`]).
    /// * `max_tokens`: longest request (prompt + output) this worker must
    ///   hold KV for — sizes the per-request HBM reservation, so admission
    ///   control reflects the actual workload rather than `max_context`.
    pub fn new(
        core: &CoreConfig,
        model: &ModelConfig,
        group: TpGroup,
        exec: ExecConfig,
        iter_tokens: usize,
        kv_share: f64,
        max_tokens: usize,
    ) -> Self {
        let tp = group.len().max(1);
        let layers = exec.layers;
        let p = plan(
            core,
            model,
            &PlanRequest {
                layers,
                tp,
                iter_tokens,
                kv_share,
            },
        );
        // Per-core KV bytes/token for this group's layer+head shard.
        let bpt = (model.kv_bytes_per_token_layer() * layers as u64 / tp as u64).max(1);
        // HBM left for KV after the streamed weight shard.
        let hbm_kv = core.hbm_bytes.saturating_sub(p.weight_hbm_bytes);
        let kv = KvCache::new(
            p.kv_bytes,
            KV_BLOCK_TOKENS, // tokens per SRAM block (fine granularity)
            hbm_kv,
            bpt,
            (max_tokens.max(1)).min(model.max_context) as u64,
        );
        StageWorker {
            group,
            exec,
            plan: p,
            kv,
            memo: None,
            surrogate: None,
        }
    }

    /// Enable prefix-sharing KV caching on this worker (builder style).
    pub fn with_prefix_cache(mut self, on: bool) -> Self {
        if on {
            self.kv.enable_prefix_cache();
        }
        self
    }

    /// Enable the demoted-prefix HBM tier on this worker (builder style;
    /// call after [`StageWorker::with_prefix_cache`] — the tier requires
    /// the prefix cache). Reserves `frac` of the worker's post-weight HBM
    /// KV capacity for cold demoted prefixes
    /// ([`DEFAULT_HBM_TIER_FRAC`] = the former fixed 1/8 share); no-op on
    /// SRAM-only chips (nothing to demote into) and when the carve would
    /// leave the spill ring unable to hold even one request
    /// ([`KvCache::enable_hbm_tier`] validates that bound).
    pub fn with_hbm_tier(mut self, on: bool, frac: f64) -> Self {
        if on {
            let cap = (self.kv.hbm_free_bytes() as f64 * frac.clamp(0.0, 1.0)) as u64;
            // cap == 0 is the documented SRAM-only no-op; a non-zero carve
            // that gets refused must not pass silently — the run would
            // report zero demotions and look like the tier was exercised
            // when it never existed.
            if !self.kv.enable_hbm_tier(cap) && cap > 0 {
                crate::log_warn!(
                    "HBM tier refused on a worker: carve of {cap} bytes (frac {frac}) \
                     would starve the spill ring; running single-tier"
                );
            }
        }
        self
    }

    /// Enable operator-latency memoization on this worker (builder style).
    pub fn with_memo(mut self, on: bool) -> Self {
        if on {
            self.memo = Some(LatencyMemo::new());
        }
        self
    }

    /// Select the simulation fidelity level on this worker (builder
    /// style). [`SimLevel::Txn`] (the default) leaves the worker
    /// bit-identical to the historical transaction-level simulator;
    /// [`SimLevel::Fast`] prices iterations through the calibrated
    /// analytic [`Surrogate`] after one transaction-level calibration run
    /// per shape class.
    pub fn with_sim_level(mut self, level: SimLevel) -> Self {
        if level == SimLevel::Fast {
            self.surrogate = Some(Surrogate::new());
        }
        self
    }

    /// Whether another request fits this worker's KV capacity.
    pub fn can_admit(&self) -> bool {
        self.kv.can_admit()
    }

    pub fn admit(&mut self, request: u64) -> bool {
        self.kv.admit(request)
    }

    /// Longest cached-and-ready prefix available for `keys` at cycle `at`
    /// (no commitment), capped at `max_tokens`.
    pub fn peek_prefix(&self, keys: &[BlockKey], max_tokens: u64, at: Cycle) -> u64 {
        self.kv.peek_prefix(keys, max_tokens, at)
    }

    /// Like [`StageWorker::peek_prefix`] but split by residency tier
    /// (SRAM-resident vs HBM-demoted match tokens).
    pub fn peek_prefix_tiered(&self, keys: &[BlockKey], max_tokens: u64, at: Cycle) -> TierMatch {
        self.kv.peek_prefix_tiered(keys, max_tokens, at)
    }

    /// Charge the HBM streams of tier promotions/demotions accumulated
    /// since the last drain on every core of this group: the HBM tier is
    /// bandwidth-priced through the same transaction-level channel model
    /// as KV spill, so moving a cold prefix is cheap but never free. No-op
    /// (and allocation-free) while the tier is off.
    pub fn charge_tier_traffic(&mut self, chip: &mut ChipSim) {
        let (promoted, demoted) = self.kv.drain_tier_traffic();
        let bytes = promoted + demoted;
        if bytes > 0 {
            for &c in &self.group.coords {
                chip.core_mut(c).hbm_access(bytes, OpClass::KvSpill);
            }
        }
    }

    /// Admit with prefix sharing at cycle `at`; returns the matched token
    /// count (0 when the prefix cache is disabled or nothing matched).
    pub fn admit_prefixed(
        &mut self,
        request: u64,
        keys: &[BlockKey],
        max_match: u64,
        at: Cycle,
    ) -> u64 {
        self.kv
            .admit_prefixed(request, keys, max_match, at)
            .unwrap_or(0)
    }

    /// Report `request`'s prefill covering its first `upto` prompt tokens
    /// by cycle `now` — makes the prefix blocks it registered matchable.
    pub fn note_prefilled(&mut self, request: u64, upto: u64, now: Cycle) {
        self.kv.note_prefilled(request, upto, now);
    }

    pub fn release(&mut self, request: u64) {
        self.kv.release(request);
    }

    /// This worker's current clock.
    pub fn now(&self, chip: &ChipSim) -> Cycle {
        group_now(chip, &self.group)
    }

    /// Advance the whole group to at least `t` (idle wait).
    pub fn advance_to(&self, chip: &mut ChipSim, t: Cycle) {
        for &c in &self.group.coords {
            chip.core_mut(c).advance_to(t);
        }
    }

    /// Execute one iteration; returns the finish cycle. Appends inside the
    /// iteration may demote cold prefixes under SRAM pressure — that tier
    /// traffic is charged on the group right after the iteration.
    pub fn run(&mut self, chip: &mut ChipSim, model: &ModelConfig, batch: &IterBatch) -> Cycle {
        if self.surrogate.is_some() {
            return self.run_fast(chip, model, batch);
        }
        let t = run_iteration_memo(
            chip,
            &self.group,
            model,
            &self.plan,
            &self.exec,
            batch,
            &mut self.kv,
            self.memo.as_mut(),
        );
        self.charge_tier_traffic(chip);
        group_now(chip, &self.group).max(t)
    }

    /// `--sim-level fast`: the first iteration of each shape class runs
    /// transaction-level to calibrate the analytic surrogate; every later
    /// iteration of the class keeps exact KV bookkeeping (append, spill
    /// writeback, tier traffic — token conservation is not approximated)
    /// but replaces operator execution with one uniform group advance of
    /// the surrogate-predicted duration.
    fn run_fast(&mut self, chip: &mut ChipSim, model: &ModelConfig, batch: &IterBatch) -> Cycle {
        if batch.is_empty() {
            return group_now(chip, &self.group);
        }
        let shape = SurrogateShape {
            tp: self.group.len().max(1) as u64,
            weight_hbm_bytes: self.plan.weight_hbm_bytes,
        };
        let key = Surrogate::key(batch);
        let analytic =
            Surrogate::analytic_iteration_cycles(&chip.cfg, model, &self.exec, shape, batch);
        let predicted = self
            .surrogate
            .as_mut()
            .expect("run_fast requires a surrogate")
            .predict(key, analytic);
        let Some(dur) = predicted else {
            // Calibration miss: run this shape class once at transaction
            // level and record the measured/analytic ratio.
            let t0 = chip.sync(&self.group.coords);
            let t = run_iteration_memo(
                chip,
                &self.group,
                model,
                &self.plan,
                &self.exec,
                batch,
                &mut self.kv,
                None,
            );
            let t1 = group_now(chip, &self.group).max(t);
            self.surrogate
                .as_mut()
                .expect("run_fast requires a surrogate")
                .calibrate(key, t1.saturating_sub(t0), analytic);
            self.charge_tier_traffic(chip);
            return t1;
        };
        // Replay: exact KV appends (spill writeback charged like the
        // detailed path), then one group-uniform advance by the predicted
        // duration, recorded as Gemm time so utilization stays plausible.
        let mut spill_bytes = 0;
        for item in &batch.items {
            spill_bytes += self.kv.append(item.request, item.q_tokens).hbm_bytes;
        }
        if spill_bytes > 0 {
            for &c in &self.group.coords {
                chip.core_mut(c).hbm_access(spill_bytes, OpClass::KvSpill);
            }
        }
        let t0 = chip.sync(&self.group.coords);
        for &c in &self.group.coords {
            let core = chip.core_mut(c);
            core.tracer.record(OpClass::Gemm, dur);
            core.advance_to(t0 + dur);
        }
        self.charge_tier_traffic(chip);
        group_now(chip, &self.group).max(t0 + dur)
    }

    /// Activation bytes handed to the next pipeline stage for a batch of
    /// `q_tokens` (one hidden-state row per token).
    pub fn handoff_bytes(&self, chip_cfg: &ChipConfig, model: &ModelConfig, q_tokens: u64) -> u64 {
        let _ = chip_cfg;
        q_tokens * model.hidden as u64 * model.dtype_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;
    use crate::model::BatchItem;
    use crate::parallel::placement::{Placement, Region};

    fn worker(chip: &ChipSim) -> StageWorker {
        let model = ModelConfig::qwen3_4b();
        let group = TpGroup::place(Region::new(0, 0, 2, 2), Placement::Ring);
        StageWorker::new(
            &chip.cfg.core,
            &model,
            group,
            ExecConfig::new(crate::parallel::partition::PartitionStrategy::OneDimK, 4, true),
            512,
            0.5,
            2048,
        )
    }

    #[test]
    fn worker_runs_iterations() {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        let mut w = worker(&chip);
        assert!(w.admit(1));
        let b = IterBatch::new(vec![BatchItem::prefill(1, 256, 256)]);
        let t = w.run(&mut chip, &model, &b);
        assert!(t > 0);
        assert_eq!(w.now(&chip), t);
        // Decode step continues from there.
        let b2 = IterBatch::new(vec![BatchItem::decode(1, 257)]);
        let t2 = w.run(&mut chip, &model, &b2);
        assert!(t2 > t);
    }

    #[test]
    fn fast_level_calibrates_once_then_replays() {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        let mut w = worker(&chip).with_sim_level(SimLevel::Fast);
        assert!(w.admit(1));
        let prefill = IterBatch::new(vec![BatchItem::prefill(1, 256, 256)]);
        let t = w.run(&mut chip, &model, &prefill);
        assert!(t > 0);
        let sur = w.surrogate.as_ref().unwrap();
        assert_eq!((sur.calibrations, sur.replays), (1, 0));
        // Decode steps: first one calibrates its class, the rest replay
        // and keep advancing time monotonically.
        let mut last = t;
        for kv_len in 257..270 {
            let b = IterBatch::new(vec![BatchItem::decode(1, kv_len)]);
            let now = w.run(&mut chip, &model, &b);
            assert!(now > last, "time must advance: {now} vs {last}");
            last = now;
        }
        let sur = w.surrogate.as_ref().unwrap();
        assert!(sur.calibrations >= 2);
        assert!(sur.replays >= 10, "replays {} calibrations {}", sur.replays, sur.calibrations);
    }

    #[test]
    fn txn_level_is_the_default_and_keeps_the_detailed_path() {
        let chip = ChipSim::new(ChipConfig::large_core());
        let w = worker(&chip).with_sim_level(SimLevel::Txn);
        assert!(w.surrogate.is_none());
        assert!(worker(&chip).surrogate.is_none());
    }

    #[test]
    fn admit_release_cycle() {
        let chip = ChipSim::new(ChipConfig::large_core());
        let mut w = worker(&chip);
        assert!(w.can_admit());
        assert!(w.admit(7));
        w.release(7);
        assert!(w.can_admit());
    }

    #[test]
    fn advance_to_is_idle_wait() {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let w = worker(&chip);
        w.advance_to(&mut chip, 12345);
        assert_eq!(w.now(&chip), 12345);
    }

    #[test]
    fn hbm_tier_frac_scales_the_carve() {
        let chip = ChipSim::new(ChipConfig::large_core());
        let free = worker(&chip).kv.hbm_free_bytes();
        let mk = |frac: f64| {
            let mut w = worker(&chip);
            w.kv.enable_prefix_cache();
            w.with_hbm_tier(true, frac)
        };
        // The default fraction reproduces the former fixed 1/8 carve
        // exactly (integer division and f64 * 0.125 agree bit-for-bit).
        let d = mk(DEFAULT_HBM_TIER_FRAC);
        assert!(d.kv.hbm_tier_enabled());
        assert_eq!(d.kv.hbm_free_bytes(), free - free / 8);
        // A bigger fraction carves a bigger region.
        let big = mk(0.5);
        assert!(big.kv.hbm_free_bytes() < d.kv.hbm_free_bytes());
        // Out-of-range fractions clamp instead of wrapping.
        let z = mk(-1.0);
        assert!(!z.kv.hbm_tier_enabled());
        assert_eq!(z.kv.hbm_free_bytes(), free);
    }
}
