//! Fine-grained SRAM block allocator (Fig. 5, left).
//!
//! The KV region of SRAM is carved into fixed-size blocks. Each request
//! owns a chain (linked list) of block IDs — blocks from different
//! requests interleave freely, exactly as in the paper's example where
//! requests 2 and 3 arrive while request 1 is mid-generation. A free list
//! recycles blocks when requests complete.

/// Sentinel for "no next block" in the chain table.
const NIL: u32 = u32::MAX;

/// A request's handle on its block chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chain {
    head: u32,
    tail: u32,
    len: u32,
}

impl Chain {
    pub fn empty() -> Self {
        Chain {
            head: NIL,
            tail: NIL,
            len: 0,
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Fixed-size block allocator over a byte capacity.
#[derive(Debug, Clone)]
pub struct BlockAllocator {
    block_bytes: u64,
    /// `next[i]` = chain successor of block `i` (NIL terminates). Blocks on
    /// the free list reuse the same table.
    next: Vec<u32>,
    free_head: u32,
    n_free: u32,
}

impl BlockAllocator {
    /// Carve `capacity_bytes` into blocks of `block_bytes`.
    pub fn new(capacity_bytes: u64, block_bytes: u64) -> Self {
        assert!(block_bytes > 0, "zero block size");
        let n = (capacity_bytes / block_bytes) as usize;
        let n = n.min(u32::MAX as usize - 1);
        // Free list initially links every block in order.
        let mut next = vec![NIL; n];
        for i in 0..n.saturating_sub(1) {
            next[i] = (i + 1) as u32;
        }
        BlockAllocator {
            block_bytes,
            next,
            free_head: if n == 0 { NIL } else { 0 },
            n_free: n as u32,
        }
    }

    pub fn block_bytes(&self) -> u64 {
        self.block_bytes
    }

    pub fn n_blocks(&self) -> usize {
        self.next.len()
    }

    pub fn n_free(&self) -> usize {
        self.n_free as usize
    }

    pub fn bytes_free(&self) -> u64 {
        self.n_free as u64 * self.block_bytes
    }

    /// Append one block to `chain`. Returns `false` (chain unchanged) when
    /// SRAM is exhausted — the caller spills to HBM instead.
    pub fn append(&mut self, chain: &mut Chain) -> bool {
        if self.free_head == NIL {
            return false;
        }
        let blk = self.free_head;
        self.free_head = self.next[blk as usize];
        self.next[blk as usize] = NIL;
        self.n_free -= 1;
        if chain.tail == NIL {
            chain.head = blk;
        } else {
            self.next[chain.tail as usize] = blk;
        }
        chain.tail = blk;
        chain.len += 1;
        true
    }

    /// Release an entire chain back to the free list (request completed).
    pub fn release(&mut self, chain: &mut Chain) {
        if chain.head == NIL {
            return;
        }
        // Splice the whole chain onto the free list head in O(1).
        self.next[chain.tail as usize] = self.free_head;
        self.free_head = chain.head;
        self.n_free += chain.len;
        *chain = Chain::empty();
    }

    /// Walk a chain's block IDs (diagnostics / tests).
    pub fn chain_blocks(&self, chain: &Chain) -> Vec<u32> {
        let mut out = Vec::with_capacity(chain.n_blocks());
        let mut cur = chain.head;
        while cur != NIL {
            out.push(cur);
            cur = self.next[cur as usize];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn carves_capacity_into_blocks() {
        let a = BlockAllocator::new(1024, 128);
        assert_eq!(a.n_blocks(), 8);
        assert_eq!(a.n_free(), 8);
        assert_eq!(a.bytes_free(), 1024);
    }

    #[test]
    fn append_until_exhausted() {
        let mut a = BlockAllocator::new(512, 128);
        let mut c = Chain::empty();
        for _ in 0..4 {
            assert!(a.append(&mut c));
        }
        assert!(!a.append(&mut c), "5th block must fail");
        assert_eq!(c.n_blocks(), 4);
        assert_eq!(a.n_free(), 0);
    }

    #[test]
    fn chains_interleave_like_fig5() {
        // Request 1 grows alone, then 2 and 3 arrive: block IDs interleave.
        let mut a = BlockAllocator::new(8 * 64, 64);
        let mut r1 = Chain::empty();
        let mut r2 = Chain::empty();
        let mut r3 = Chain::empty();
        a.append(&mut r1);
        a.append(&mut r1);
        a.append(&mut r2);
        a.append(&mut r3);
        a.append(&mut r1); // r1's third block is *after* r2/r3's first
        assert_eq!(a.chain_blocks(&r1), vec![0, 1, 4]);
        assert_eq!(a.chain_blocks(&r2), vec![2]);
        assert_eq!(a.chain_blocks(&r3), vec![3]);
    }

    #[test]
    fn release_recycles_blocks() {
        let mut a = BlockAllocator::new(4 * 64, 64);
        let mut r1 = Chain::empty();
        let mut r2 = Chain::empty();
        for _ in 0..2 {
            a.append(&mut r1);
            a.append(&mut r2);
        }
        assert_eq!(a.n_free(), 0);
        a.release(&mut r1);
        assert_eq!(a.n_free(), 2);
        assert!(r1.is_empty());
        // Freed blocks are reusable by a new request.
        let mut r3 = Chain::empty();
        assert!(a.append(&mut r3));
        assert!(a.append(&mut r3));
        assert!(!a.append(&mut r3));
        // r2 is untouched.
        assert_eq!(r2.n_blocks(), 2);
    }

    #[test]
    fn zero_capacity_always_fails() {
        let mut a = BlockAllocator::new(63, 64); // less than one block
        let mut c = Chain::empty();
        assert!(!a.append(&mut c));
    }

    #[test]
    fn release_empty_chain_is_noop() {
        let mut a = BlockAllocator::new(256, 64);
        let mut c = Chain::empty();
        a.release(&mut c);
        assert_eq!(a.n_free(), 4);
    }

    #[test]
    fn prop_no_block_shared_between_chains() {
        check("block exclusivity", 128, |rng| {
            let n_blocks = rng.range(1, 32);
            let mut a = BlockAllocator::new(n_blocks as u64 * 64, 64);
            let mut chains = vec![Chain::empty(); rng.range(1, 6)];
            // Random interleaving of appends and releases.
            for _ in 0..rng.range(1, 64) {
                let i = rng.range(0, chains.len());
                if rng.chance(0.8) {
                    a.append(&mut chains[i]);
                } else {
                    a.release(&mut chains[i]);
                }
            }
            // Invariant: all live blocks distinct, accounting consistent.
            let mut seen = std::collections::HashSet::new();
            let mut live = 0;
            for c in &chains {
                for b in a.chain_blocks(c) {
                    assert!(seen.insert(b), "block {b} in two chains");
                    live += 1;
                }
            }
            assert_eq!(live + a.n_free(), a.n_blocks());
        });
    }
}
