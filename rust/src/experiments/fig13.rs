//! Fig. 13 — PD fusion hardware sweep: end-to-end latency vs input token
//! length × per-core SRAM size {16, 32, 48 MB} × pipeline stage count
//! {12, 18, 32} for Qwen3-8B (TP=4) on the 256-core chip.
//!
//! Fewer stages ⇒ more layers per stage ⇒ more data parallelism but more
//! SRAM pressure (spilling); the sweet spot moves with SRAM size, which is
//! the paper's point.

use crate::config::{ChipConfig, ModelConfig, WorkloadConfig};
use crate::experiments::Opts;
use crate::serving::pd_fusion::{simulate_fusion, FusionConfig};
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};

pub fn run_cell(
    model: &ModelConfig,
    input: usize,
    output: usize,
    n_requests: usize,
    sram_mb: u64,
    stages: usize,
) -> anyhow::Result<f64> {
    let chip_cfg = ChipConfig::small_core().with_sram_mb(sram_mb);
    let mut chip = ChipSim::new(chip_cfg);
    let w = WorkloadConfig::fixed_ratio(input, output, n_requests);
    let cfg = FusionConfig {
        tp: 4,
        stages,
        ..FusionConfig::default()
    };
    let m = simulate_fusion(&mut chip, model, &w, &cfg)?;
    Ok(m.e2e_s().max())
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let model = ModelConfig::qwen3_8b();
    let output = opts.pick(64, 8);
    let n = opts.pick(8, 2);
    let inputs = opts.pick(vec![512usize, 2048, 8192], vec![128, 512]);
    let srams = opts.pick(vec![16u64, 32, 48], vec![16, 48]);
    let stage_counts = opts.pick(vec![12usize, 18, 32], vec![12, 32]);

    let mut tables = Vec::new();
    for &input in &inputs {
        let mut t = Table::new(
            &format!(
                "Fig 13 — PD fusion e2e latency (s), Qwen3-8B TP=4 256 cores, input {input}"
            ),
            &["sram MB", "pp12", "pp18", "pp32"],
        );
        for &sram in &srams {
            let mut row = vec![sram.to_string()];
            for &st in &[12usize, 18, 32] {
                if !stage_counts.contains(&st) {
                    row.push("-".into());
                    continue;
                }
                row.push(f3(run_cell(&model, input, output, n, sram, st)?));
            }
            t.row(&row);
        }
        tables.push(t);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bigger_sram_helps_under_fusion_pressure() {
        // Paper: 16 → 32/48 MB SRAM gives a large speedup under fusion.
        let m = ModelConfig::qwen3_8b();
        let small = run_cell(&m, 256, 16, 2, 16, 12).unwrap();
        let big = run_cell(&m, 256, 16, 2, 48, 12).unwrap();
        assert!(big <= small, "48MB {big} vs 16MB {small}");
    }

    #[test]
    fn more_stages_help_when_sram_is_small() {
        // With small SRAM, more stages = fewer layers/core = less spill.
        let m = ModelConfig::qwen3_8b();
        let pp12 = run_cell(&m, 256, 16, 2, 16, 12).unwrap();
        let pp32 = run_cell(&m, 256, 16, 2, 16, 32).unwrap();
        assert!(pp32 <= pp12 * 1.05, "pp32 {pp32} vs pp12 {pp12}");
    }

    #[test]
    fn table_shape() {
        let tables = run(&Opts::fast()).unwrap();
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].n_rows(), 2);
    }
}
