//! Independent coarse analytic model of an NPU running an LLM — the
//! stand-in for the Ascend-910B hardware measurements of Fig. 7 (left).
//!
//! The paper validates NpuSim by comparing simulated latency against real
//! hardware across batch sizes and decode lengths; the claim is *trend
//! alignment*. We cannot run a 910B, so this module provides a coarse,
//! independently-coded roofline model (no shared code with the simulator's
//! per-operator machinery) to play the hardware's role: if NpuSim tracks
//! this model's trends while adding contention detail, the validation
//! methodology is preserved (DESIGN.md "Substitutions").

use crate::config::{ChipConfig, ModelConfig};

/// Estimated end-to-end latency (seconds) of `batch` requests, each with
/// `input_len` prompt tokens and `output_len` generated tokens, on `chip`.
pub fn e2e_latency_s(
    chip: &ChipConfig,
    model: &ModelConfig,
    batch: u64,
    input_len: u64,
    output_len: u64,
) -> f64 {
    let n_cores = chip.n_cores() as f64;
    let freq_hz = chip.freq_mhz * 1e6;
    // Aggregate chip capabilities.
    let peak_flops = n_cores * (chip.core.sa_dim * chip.core.sa_dim) as f64 * 2.0 * freq_hz;
    let hbm_bw = n_cores * chip.core.hbm_bw_gbps * 1e9; // bytes/s
    let weight_bytes = model.weight_bytes() as f64;
    let sram_total = n_cores * chip.core.sram_bytes as f64;
    // Weights resident in SRAM are not re-streamed each iteration.
    let streamed = (weight_bytes - sram_total).max(0.0);

    // Prefill: compute-bound roofline at a typical large-GEMM efficiency.
    let prefill_flops = model.fwd_flops(batch * input_len, input_len) as f64;
    let prefill_s = (prefill_flops / (peak_flops * 0.6)).max(streamed / hbm_bw);

    // Decode: one token per request per step, memory-bound: every step
    // re-reads the streamed weights and the KV cache.
    let kv_per_tok = model.kv_bytes_per_token() as f64;
    let mut decode_s = 0.0;
    let steps = output_len;
    for s in 0..steps {
        let ctx = input_len as f64 + s as f64;
        let flops = model.fwd_flops(batch, ctx as u64) as f64;
        let bytes = streamed + batch as f64 * ctx * kv_per_tok;
        decode_s += (flops / (peak_flops * 0.08)).max(bytes / hbm_bw);
    }
    prefill_s + decode_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_grows_with_batch_and_length() {
        let chip = ChipConfig::ascend910b_like();
        let m = ModelConfig::qwen3_4b();
        let base = e2e_latency_s(&chip, &m, 8, 256, 128);
        assert!(e2e_latency_s(&chip, &m, 64, 256, 128) > base);
        assert!(e2e_latency_s(&chip, &m, 8, 256, 256) > base);
        assert!(base > 0.0);
    }

    #[test]
    fn plausible_absolute_range() {
        // A 4B model decoding 128 tokens at batch 8 on a 910B-class chip
        // should land in O(0.1–100 s), not microseconds or hours.
        let chip = ChipConfig::ascend910b_like();
        let m = ModelConfig::qwen3_4b();
        let t = e2e_latency_s(&chip, &m, 8, 256, 128);
        assert!(t > 0.01 && t < 100.0, "t={t}");
    }
}
