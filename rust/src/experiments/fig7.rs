//! Fig. 7 — simulator validation.
//!
//! Left: NpuSim end-to-end latency of Qwen3-4B vs the reference hardware
//! model across decode lengths {128, 256} and batch sizes {8..64}
//! (Ascend-910B stand-in; see [`crate::experiments::reference_hw`]).
//!
//! Right: detailed (TLM + cycle-accurate NoC) vs fast (analytic) modes on
//! memory-intensive (C1–C3) and compute-intensive (C4–C6) workloads —
//! simulated-latency deviation and wall-clock speedup.

use crate::config::{ChipConfig, MemSimMode, ModelConfig, NocSimMode, WorkloadConfig};
use crate::experiments::{reference_hw, Opts};
use crate::serving::metrics::Metrics;
use crate::serving::pd_fusion::{simulate_fusion, FusionConfig};
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};

fn simulate(chip_cfg: ChipConfig, model: &ModelConfig, w: &WorkloadConfig) -> (Metrics, f64) {
    let mut chip = ChipSim::new(chip_cfg);
    // Whole-chip TP (how real deployments run one model on one device —
    // and what the reference hardware model assumes).
    let tp = chip.cfg.n_cores();
    let cfg = FusionConfig {
        tp,
        stages: 1,
        ..FusionConfig::default()
    };
    let t0 = std::time::Instant::now();
    let m = simulate_fusion(&mut chip, model, w, &cfg).expect("simulation failed");
    (m, t0.elapsed().as_secs_f64())
}

/// Fig. 7 left: simulator-vs-hardware-model latency.
pub fn run_validation(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let model = ModelConfig::qwen3_4b();
    let chip_cfg = ChipConfig::ascend910b_like();
    let input_len = opts.pick(256, 64);
    let decode_lens = opts.pick([128u64, 256], [16, 32]);
    let batches = if opts.fast {
        vec![8u64]
    } else {
        vec![8, 16, 32, 64]
    };

    let mut t = Table::new(
        "Fig 7 (left) — Qwen3-4B e2e latency: NpuSim vs reference hardware model",
        &["decode len", "batch", "npusim (s)", "reference (s)", "ratio"],
    );
    for &dl in &decode_lens {
        for &b in &batches {
            let w = WorkloadConfig::fixed_ratio(input_len, dl as usize, b as usize);
            let (m, _) = simulate(chip_cfg.clone(), &model, &w);
            let sim_s = m.e2e_s().max();
            let hw_s = reference_hw::e2e_latency_s(&chip_cfg, &model, b, input_len as u64, dl);
            t.row(&[
                dl.to_string(),
                b.to_string(),
                f3(sim_s),
                f3(hw_s),
                f3(sim_s / hw_s),
            ]);
        }
    }
    Ok(vec![t])
}

/// Fig. 7 right: detailed vs fast simulation modes.
///
/// Memory-intensive cases run PD disaggregation (concurrent KV transfers
/// crossing the decode groups' collective rings — non-deterministic
/// latencies the analytic `Fast` models cannot capture, the paper's
/// argument for TLM memory + cycle-accurate routing); compute-intensive
/// cases run whole-chip TP prefill (deterministic, so both modes agree).
fn simulate_contended(
    chip_cfg: ChipConfig,
    model: &ModelConfig,
    w: &WorkloadConfig,
) -> (Metrics, f64) {
    let mut chip = ChipSim::new(chip_cfg);
    // PD disaggregation: prefill->decode KV transfers cross the decode
    // region's columns while the decode groups' collective rings rotate on
    // the same links — the genuinely contended traffic pattern.
    let cfg = crate::serving::pd_disagg::DisaggConfig {
        prefill_strategy: crate::parallel::partition::PartitionStrategy::OneDimMN,
        max_decode_batch: 8,
        ..crate::serving::pd_disagg::DisaggConfig::p42_d21()
    };
    let t0 = std::time::Instant::now();
    let m = crate::serving::pd_disagg::simulate_disagg(&mut chip, model, w, &cfg)
        .expect("simulation failed");
    (m, t0.elapsed().as_secs_f64())
}

pub fn run_mode_comparison(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let model = ModelConfig::qwen3_4b();
    // C1–C3 memory-intensive (decode-heavy, batched GEMV + KV streaming);
    // C4–C6 compute-intensive (prefill-heavy large GEMMs).
    let n = opts.pick(8, 2);
    // `true` = memory/interconnect-intensive (disagg with concurrent KV
    // transfers + decode collectives: non-deterministic latencies); `false`
    // = compute-intensive (whole-chip TP prefill: deterministic).
    let cases: Vec<(&str, bool, WorkloadConfig)> = vec![
        ("C1 mem (1:8)", true, WorkloadConfig::fixed_ratio(opts.pick(64, 16), opts.pick(512, 48), n)),
        ("C2 mem (1:4)", true, WorkloadConfig::fixed_ratio(opts.pick(128, 16), opts.pick(512, 32), n)),
        ("C3 mem (1:2)", true, WorkloadConfig::fixed_ratio(opts.pick(256, 32), opts.pick(512, 32), n)),
        ("C4 comp (4:1)", false, WorkloadConfig::fixed_ratio(opts.pick(2048, 128), opts.pick(32, 8), n)),
        ("C5 comp (8:1)", false, WorkloadConfig::fixed_ratio(opts.pick(4096, 256), opts.pick(32, 8), n)),
        ("C6 comp (16:1)", false, WorkloadConfig::fixed_ratio(opts.pick(8192, 512), opts.pick(16, 4), n)),
    ];

    let mut t = Table::new(
        "Fig 7 (right) — detailed vs fast simulation: accuracy and wall-clock speedup",
        &[
            "case",
            "detailed (s)",
            "fast (s)",
            "latency err %",
            "wall speedup",
        ],
    );
    for (name, mem_bound, w) in cases {
        let detailed_cfg = ChipConfig::large_core();
        let fast_cfg =
            ChipConfig::large_core().with_sim_modes(MemSimMode::Fast, NocSimMode::Fast);
        let run = if mem_bound { simulate_contended } else { simulate };
        let (md, wall_d) = run(detailed_cfg, &model, &w);
        let (mf, wall_f) = run(fast_cfg, &model, &w);
        let (ld, lf) = (md.e2e_s().max(), mf.e2e_s().max());
        t.row(&[
            name.to_string(),
            f3(ld),
            f3(lf),
            f3((lf - ld).abs() / ld * 100.0),
            f3(wall_d / wall_f.max(1e-9)),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation_tracks_reference_trends() {
        let tables = run_validation(&Opts::fast()).unwrap();
        assert_eq!(tables.len(), 1);
        // Ratios must stay within ~an order of magnitude of the
        // independent hardware model (the paper's trend-alignment claim;
        // fast mode runs token counts far below the model's sweet spot,
        // so the band is generous — the full run is much tighter).
        let csv = tables[0].to_csv();
        for line in csv.lines().skip(1) {
            let ratio: f64 = line.split(',').last().unwrap().parse().unwrap();
            assert!(ratio > 0.05 && ratio < 20.0, "ratio off-trend: {line}");
        }
    }

    #[test]
    fn fast_mode_diverges_from_detailed_but_runs() {
        let tables = run_mode_comparison(&Opts::fast()).unwrap();
        assert_eq!(tables[0].n_rows(), 6);
    }
}
