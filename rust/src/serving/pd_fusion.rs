//! PD fusion (§4.3.2): every worker pipeline co-locates prefill and decode.
//!
//! The scheduler gives each iteration a fixed token **budget**: a decode
//! step consumes one unit, a prefill chunk consumes `chunk` units. Decode
//! steps are admitted first (they bound TBT); leftover budget is assigned
//! to chunked prefill (SARATHI-style), so prefill never stalls decoding by
//! more than one chunk.

use crate::config::{ModelConfig, WorkloadConfig};
use crate::model::{BatchItem, IterBatch};
use crate::parallel::partition::PartitionStrategy;
use crate::parallel::placement::Placement;
use crate::serving::layout::PipelineLayout;
use crate::serving::metrics::{Metrics, RequestRecord};
use crate::serving::request::{self, Request};
use crate::serving::worker::StageWorker;
use crate::sim::chip::ChipSim;
use crate::sim::tracer::OpClass;
use crate::util::units::{secs_to_cycles, Cycle};
use std::collections::VecDeque;

/// PD-fusion serving configuration.
#[derive(Debug, Clone, Copy)]
pub struct FusionConfig {
    /// TP degree of each pipeline stage.
    pub tp: usize,
    /// Pipeline stages (fewer stages = more layers and more DP pipelines).
    pub stages: usize,
    pub placement: Placement,
    pub strategy: PartitionStrategy,
    /// Chunked-prefill chunk size in tokens.
    pub chunk: usize,
    /// Per-iteration token budget (decode=1 unit, prefill chunk=`chunk`).
    pub budget: usize,
    /// Max concurrent requests per pipeline.
    pub max_batch: usize,
    /// SRAM remainder split between KV and weights.
    pub kv_share: f64,
}

impl Default for FusionConfig {
    fn default() -> Self {
        // §4.3.2: fusion prefers TP for both phases; chunked prefill keeps
        // the GEMM M small, where the AllReduce partition wins (§5.6).
        FusionConfig {
            tp: 4,
            stages: 4,
            placement: Placement::Ring,
            strategy: PartitionStrategy::OneDimK,
            chunk: 256,
            budget: 288,
            max_batch: 32,
            kv_share: 0.6,
        }
    }
}

/// In-flight request state.
#[derive(Debug, Clone, Copy)]
struct Active {
    req: Request,
    /// Prompt tokens already prefilled.
    prefilled: u64,
    /// Output tokens generated (first comes from the final prefill chunk).
    generated: u64,
    first_token: Option<Cycle>,
    /// Earliest cycle the next decode step may start (autoregressive
    /// dependency — this is what makes deep pipelines hurt decode).
    ready_at: Cycle,
}

impl Active {
    fn is_prefilling(&self) -> bool {
        self.prefilled < self.req.input_len as u64
    }

    fn is_done(&self) -> bool {
        !self.is_prefilling() && self.generated >= self.req.output_len as u64
    }
}

struct Pipe {
    stages: Vec<StageWorker>,
    queue: VecDeque<Request>,
    active: Vec<Active>,
}

impl Pipe {
    fn stage0_now(&self, chip: &ChipSim) -> Cycle {
        self.stages[0].now(chip)
    }

    /// Earliest cycle at which this pipe can do useful work, or `None`.
    fn next_action(&self, chip: &ChipSim) -> Option<Cycle> {
        let now = self.stage0_now(chip);
        if self.active.iter().any(|a| a.is_prefilling()) {
            return Some(now);
        }
        let next_decode = self
            .active
            .iter()
            .filter(|a| !a.is_done())
            .map(|a| a.ready_at)
            .min();
        if let Some(t) = next_decode {
            return Some(now.max(t));
        }
        self.queue
            .front()
            .map(|r| now.max(secs_to_cycles(r.arrival_s, chip_freq(chip))))
    }
}

fn chip_freq(chip: &ChipSim) -> f64 {
    chip.cfg.freq_mhz
}

/// Simulate a full workload under PD fusion; returns the serving metrics.
pub fn simulate_fusion(
    chip: &mut ChipSim,
    model: &ModelConfig,
    workload: &WorkloadConfig,
    cfg: &FusionConfig,
) -> anyhow::Result<Metrics> {
    simulate_fusion_requests(chip, model, request::generate(workload), cfg)
}

/// Like [`simulate_fusion`] but over an explicit request list (trace
/// replay — see [`crate::serving::trace`]). Requests must be sorted by
/// arrival time.
pub fn simulate_fusion_requests(
    chip: &mut ChipSim,
    model: &ModelConfig,
    reqs: Vec<crate::serving::request::Request>,
    cfg: &FusionConfig,
) -> anyhow::Result<Metrics> {
    let layout = PipelineLayout::build(
        chip.cfg.rows,
        chip.cfg.cols,
        cfg.tp,
        cfg.stages,
        cfg.placement,
    )?;
    let lps = layout.layers_per_stage(model.layers);
    let core = chip.cfg.core;
    let max_tokens = reqs.iter().map(|r| r.total_tokens()).max().unwrap_or(1);
    let mut pipes: Vec<Pipe> = layout
        .pipelines
        .iter()
        .map(|groups| Pipe {
            stages: groups
                .iter()
                .enumerate()
                .map(|(s, g)| {
                    StageWorker::new(
                        &core,
                        model,
                        g.clone(),
                        cfg.strategy,
                        lps[s].max(1),
                        s + 1 == groups.len(),
                        cfg.budget.max(cfg.chunk),
                        cfg.kv_share,
                        max_tokens,
                    )
                })
                .collect(),
            queue: VecDeque::new(),
            active: Vec::new(),
        })
        .collect();
    anyhow::ensure!(!pipes.is_empty(), "no pipelines fit the chip");

    let total = reqs.len();
    let n_pipes = pipes.len();
    for (i, r) in reqs.into_iter().enumerate() {
        pipes[i % n_pipes].queue.push_back(r);
    }

    let freq = chip_freq(chip);
    let mut metrics = Metrics::new(freq);
    let mut done = 0usize;
    let mut guard = 0u64;
    while done < total {
        guard += 1;
        anyhow::ensure!(
            guard < 4_000_000,
            "fusion scheduler livelock: {done}/{total} done"
        );
        // Pick the pipeline with the earliest actionable work.
        let (pi, t) = pipes
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.next_action(chip).map(|t| (i, t)))
            .min_by_key(|&(_, t)| t)
            .ok_or_else(|| anyhow::anyhow!("deadlock: {done}/{total} requests done"))?;
        done += tick(chip, model, cfg, &mut pipes[pi], t, &mut metrics, freq);
    }
    Ok(metrics)
}

/// One scheduler iteration on one pipeline. Returns completions.
fn tick(
    chip: &mut ChipSim,
    model: &ModelConfig,
    cfg: &FusionConfig,
    pipe: &mut Pipe,
    t: Cycle,
    metrics: &mut Metrics,
    freq: f64,
) -> usize {
    pipe.stages[0].advance_to(chip, t);
    let now = pipe.stage0_now(chip);

    // Admit arrived requests while capacity lasts.
    while let Some(front) = pipe.queue.front() {
        let arrived = secs_to_cycles(front.arrival_s, freq) <= now;
        let capacity =
            pipe.active.len() < cfg.max_batch && pipe.stages.iter().all(|s| s.can_admit());
        if !arrived || !capacity {
            break;
        }
        let r = pipe.queue.pop_front().unwrap();
        for s in &mut pipe.stages {
            s.admit(r.id);
        }
        pipe.active.push(Active {
            req: r,
            prefilled: 0,
            generated: 0,
            first_token: None,
            ready_at: 0,
        });
    }

    // Build the fused batch under the token budget: decode first. Decode
    // items are additionally capped to 1/stages of the ready set so that
    // consecutive ticks form microbatches that *pipeline* through the
    // stages instead of draining the whole pipe per token (items not taken
    // now are taken by the immediately following tick on stage 0).
    let mut items = Vec::new();
    let mut budget = cfg.budget as u64;
    let mut decode_idx = Vec::new();
    let mut prefill_idx = Vec::new();
    let n_ready = pipe
        .active
        .iter()
        .filter(|a| !a.is_done() && !a.is_prefilling() && a.ready_at <= now)
        .count();
    let micro_cap = n_ready.div_ceil(pipe.stages.len().max(1)).max(1);
    for (i, a) in pipe.active.iter().enumerate() {
        if a.is_done() {
            continue;
        }
        if !a.is_prefilling()
            && a.ready_at <= now
            && budget > 0
            && decode_idx.len() < micro_cap
        {
            items.push(BatchItem::decode(
                a.req.id,
                a.req.input_len as u64 + a.generated,
            ));
            decode_idx.push(i);
            budget -= 1;
        }
    }
    for (i, a) in pipe.active.iter().enumerate() {
        if a.is_prefilling() && budget > 0 {
            let remaining = a.req.input_len as u64 - a.prefilled;
            let chunk = remaining.min(cfg.chunk as u64).min(budget);
            items.push(BatchItem::prefill(a.req.id, chunk, a.prefilled + chunk));
            prefill_idx.push((i, chunk));
            budget -= chunk;
        }
    }
    if items.is_empty() {
        return 0;
    }
    let batch = IterBatch::new(items);

    // Stream the batch through the pipeline stages.
    let q = batch.total_q_tokens();
    let mut finish = 0;
    for s in 0..pipe.stages.len() {
        finish = pipe.stages[s].run(chip, model, &batch);
        if s + 1 < pipe.stages.len() {
            let bytes = pipe.stages[s].handoff_bytes(&chip.cfg.clone(), model, q);
            let src = pipe.stages[s].group.coords[0];
            let dst = pipe.stages[s + 1].group.coords[0];
            let tr = chip.send(src, dst, bytes, OpClass::P2P);
            finish = finish.max(tr.finish);
        }
    }

    // Update request states.
    let mut completions = 0;
    for (i, chunk) in prefill_idx {
        let a = &mut pipe.active[i];
        a.prefilled += chunk;
        if !a.is_prefilling() {
            // Final prefill chunk emits the first output token.
            a.first_token = Some(finish);
            a.generated = 1;
            a.ready_at = finish;
        }
    }
    for i in decode_idx {
        let a = &mut pipe.active[i];
        a.generated += 1;
        a.ready_at = finish;
    }
    // Retire completed requests.
    let mut i = 0;
    while i < pipe.active.len() {
        if pipe.active[i].is_done() {
            let a = pipe.active.swap_remove(i);
            for s in &mut pipe.stages {
                s.release(a.req.id);
            }
            metrics.record(RequestRecord {
                id: a.req.id,
                arrival: secs_to_cycles(a.req.arrival_s, freq),
                first_token: a.first_token.unwrap_or(finish),
                finish,
                input_tokens: a.req.input_len as u64,
                output_tokens: a.req.output_len as u64,
            });
            completions += 1;
        } else {
            i += 1;
        }
    }
    completions
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    fn run(workload: &WorkloadConfig, cfg: &FusionConfig) -> Metrics {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        simulate_fusion(&mut chip, &model, workload, cfg).unwrap()
    }

    #[test]
    fn completes_all_requests() {
        let w = WorkloadConfig::fixed_ratio(128, 16, 8);
        let m = run(&w, &FusionConfig::default());
        assert_eq!(m.n_requests(), 8);
        assert!(m.tokens_per_s() > 0.0);
    }

    #[test]
    fn ttft_before_finish_and_ordered() {
        let w = WorkloadConfig::fixed_ratio(256, 32, 4);
        let m = run(&w, &FusionConfig::default());
        for r in m.records() {
            assert!(r.first_token >= r.arrival);
            assert!(r.finish >= r.first_token);
            assert_eq!(r.output_tokens, 32);
        }
    }

    #[test]
    fn streaming_arrivals_work() {
        let w = WorkloadConfig::decode_dominated(6);
        let m = run(&w, &FusionConfig::default());
        assert_eq!(m.n_requests(), 6);
        // Later arrivals cannot finish before they arrive.
        for r in m.records() {
            assert!(r.finish > r.arrival);
        }
    }

    #[test]
    fn tp_beats_pp_for_decode_tbt_at_equal_cores() {
        // §4.3.1/§4.3.2: at the same core count, tensor parallelism gives
        // lower decode latency than pipeline parallelism (which is why
        // fusion prefers TP) — 32 cores as TP16×2 stages vs TP4×8 stages.
        let w = WorkloadConfig::fixed_ratio(64, 64, 2);
        let pp_heavy = run(
            &w,
            &FusionConfig {
                tp: 4,
                stages: 8,
                ..FusionConfig::default()
            },
        );
        let tp_heavy = run(
            &w,
            &FusionConfig {
                tp: 16,
                stages: 2,
                ..FusionConfig::default()
            },
        );
        assert!(
            tp_heavy.tbt_s().mean() < pp_heavy.tbt_s().mean(),
            "tp16/pp2 {} vs tp4/pp8 {}",
            tp_heavy.tbt_s().mean(),
            pp_heavy.tbt_s().mean()
        );
    }

    #[test]
    fn budget_bounds_prefill_interference() {
        // With decode in flight, an unbounded budget lets a whole long
        // prompt join one iteration and stall every decode step in it; the
        // chunked budget bounds that interference (tail TBT).
        let w = WorkloadConfig::fixed_ratio(2048, 256, 6)
            .with_arrival(crate::config::ArrivalProcess::Poisson { rate: 3.0 });
        let small = run(
            &w,
            &FusionConfig {
                budget: 160,
                chunk: 128,
                ..FusionConfig::default()
            },
        );
        let large = run(
            &w,
            &FusionConfig {
                budget: 4096,
                chunk: 4096,
                ..FusionConfig::default()
            },
        );
        let (s99, l99) = (small.tbt_s().p99(), large.tbt_s().p99());
        assert!(
            s99 <= l99,
            "chunked p99 TBT {s99} should not exceed unchunked {l99}"
        );
    }
}
