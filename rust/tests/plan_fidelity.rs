//! Planner fidelity: the analytic Table-2 cost model must *order* the
//! partition strategies the same way the transaction-level simulator
//! does — that ordering is everything the auto-planner's ranking rests
//! on.
//!
//! Two layers:
//!
//! 1. A property test over random GEMM shapes: whenever the analytic
//!    per-strategy comm costs differ decisively (≥ 4x — the regime where
//!    overlap effects cannot flip the order), the simulated `dist_gemm`
//!    latencies on a small mesh must order the same way.
//! 2. Golden pins that `--plan auto` is deterministic for the seed
//!    configurations and that its ranked space stays feasible and
//!    well-formed end to end.

use npusim::config::{ChipConfig, ModelConfig, WorkloadConfig};
use npusim::model::exec::dist_gemm;
use npusim::parallel::partition::{partition_cost, PartitionStrategy};
use npusim::parallel::placement::{Placement, Region, TpGroup};
use npusim::parallel::plan::{self, DeploymentPlan};
use npusim::serving::scheduler::SchedulerConfig;
use npusim::sim::chip::ChipSim;
use npusim::util::prop::check;

/// Simulated latency of one `[m,k]×[k,n]` GEMM under `strategy` on a
/// fresh 2×2 ring group (weights SRAM-resident, so comm and compute are
/// the only terms — the same ones the Table-2 model scores).
fn sim_gemm_cycles(strategy: PartitionStrategy, m: u64, k: u64, n: u64) -> u64 {
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let group = TpGroup::place(Region::new(0, 0, 2, 2), Placement::Ring);
    dist_gemm(&mut chip, &group, strategy, m, k, n, 0)
}

#[test]
fn prop_analytic_comm_ordering_matches_simulated_dist_gemm() {
    // Random shapes from the regimes the planner actually distinguishes:
    // short-M (decode / chunked prefill) and long-M (whole-prompt
    // prefill), square-ish hidden dims. Assert only on decisive analytic
    // gaps (≥ 4x) — below that, the MN strategy's compute/comm overlap
    // (which Table 2 deliberately does not model) can legitimately absorb
    // the difference.
    check("analytic ordering matches sim", 24, |rng| {
        let k = 1024u64 << rng.range(0, 3); // 1024 | 2048 | 4096
        let n = 1024u64 << rng.range(0, 3);
        let m = if rng.range(0, 2) == 0 {
            rng.range_u64(16, 65) // decode-ish
        } else {
            4 * k + rng.range_u64(0, 4096) // long prefill
        };
        let a_mn = partition_cost(PartitionStrategy::OneDimMN, 4, m, k, n, 1).total_comm;
        let a_k = partition_cost(PartitionStrategy::OneDimK, 4, m, k, n, 1).total_comm;
        if a_mn.max(a_k) < 4.0 * a_mn.min(a_k) {
            return; // not decisive — no claim
        }
        let s_mn = sim_gemm_cycles(PartitionStrategy::OneDimMN, m, k, n);
        let s_k = sim_gemm_cycles(PartitionStrategy::OneDimK, m, k, n);
        assert_eq!(
            a_k < a_mn,
            s_k < s_mn,
            "ordering flip at m={m} k={k} n={n}: analytic (k {a_k}, mn {a_mn}) \
             vs simulated (k {s_k}, mn {s_mn})"
        );
    });
}

#[test]
fn auto_plan_is_deterministic_and_projects_onto_buildable_schedulers() {
    // The CLI seed configs: `--plan auto` must resolve to the same plan
    // every run, and every ranked candidate must project onto a scheduler
    // config without error (the planner may only emit feasible plans).
    let chip = ChipConfig::large_core();
    let model = ModelConfig::qwen3_4b();
    let w = WorkloadConfig::decode_dominated(16);
    let a = plan::auto_plan(&chip, &model, &w).unwrap();
    let b = plan::auto_plan(&chip, &model, &w).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.plan, y.plan);
    }
    for c in &a {
        SchedulerConfig::from_plan(&c.plan)
            .unwrap_or_else(|e| panic!("{}: {e:#}", c.plan.name));
    }
    // Scores rank ascending except where the documented confidence
    // hysteresis promoted the canonical fused shape to the front.
    for pair in a.windows(2).skip(1) {
        assert!(
            pair[0].score.total_cycles <= pair[1].score.total_cycles,
            "{} ({}) ranked above {} ({})",
            pair[0].plan.name,
            pair[0].score.total_cycles,
            pair[1].plan.name,
            pair[1].score.total_cycles
        );
    }
}

#[test]
fn preset_plans_round_trip_through_scheduler_configs() {
    for preset in DeploymentPlan::presets() {
        let sys = SchedulerConfig::from_plan(&preset)
            .unwrap_or_else(|e| panic!("{}: {e:#}", preset.name));
        assert_eq!(sys.name(), preset.mode.name(), "{}", preset.name);
    }
}
