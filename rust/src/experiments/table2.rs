//! Table 2: communication and memory cost of the tensor-partition
//! strategies — the analytic formulas, cross-checked against the bytes the
//! simulated NoC actually moved.

use crate::config::{ChipConfig, ModelConfig};
use crate::experiments::Opts;
use crate::model::exec::dist_gemm;
use crate::parallel::partition::{partition_cost, PartitionStrategy};
use crate::parallel::placement::{Placement, Region, TpGroup};
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};

const STRATEGIES: [PartitionStrategy; 4] = [
    PartitionStrategy::InputOnly,
    PartitionStrategy::OneDimMN,
    PartitionStrategy::OneDimK,
    PartitionStrategy::TwoDim { rows: 2, cols: 2 },
];

/// Simulated NoC bytes per core for one distributed GEMM.
fn simulated_comm_per_core(strategy: PartitionStrategy, m: u64, k: u64, n: u64) -> f64 {
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let group = TpGroup::place(Region::new(0, 0, 2, 2), Placement::Ring);
    dist_gemm(&mut chip, &group, strategy, m, k, n, 0);
    chip.mesh.stats().bytes as f64 / group.len() as f64 / chip.cfg.dtype_bytes as f64
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let model = ModelConfig::qwen3_8b();
    let (m, k, n) = (
        opts.pick(1024, 256),
        model.hidden as u64,
        model.hidden as u64,
    );
    let tp = 4;

    let mut t = Table::new(
        &format!("Table 2 — partition costs for GEMM [{m},{k}]x[{k},{n}], {tp} cores (elements)"),
        &[
            "strategy",
            "input/core",
            "weight/core",
            "output/core",
            "analytic comm",
            "simulated comm",
            "err %",
            "max hop",
        ],
    );
    for s in STRATEGIES {
        let c = partition_cost(s, tp, m, k, n, 2);
        let sim = simulated_comm_per_core(s, m, k, n);
        // The AllReduce sim moves ceil(bytes/num) chunks; tiny rounding ok.
        let err = if c.total_comm == 0.0 {
            (sim - c.total_comm).abs()
        } else {
            (sim - c.total_comm).abs() / c.total_comm * 100.0
        };
        t.row(&[
            s.name().to_string(),
            f3(c.input_per_core),
            f3(c.weight_per_core),
            f3(c.output_per_core),
            f3(c.total_comm),
            f3(sim),
            f3(err),
            format!("0~{}", c.max_hop),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_comm_matches_analytic_within_rounding() {
        let (m, k, n) = (512, 4096, 4096);
        for s in [PartitionStrategy::OneDimMN, PartitionStrategy::OneDimK] {
            let analytic = partition_cost(s, 4, m, k, n, 2).total_comm;
            let sim = simulated_comm_per_core(s, m, k, n);
            let err = (sim - analytic).abs() / analytic;
            assert!(err < 0.05, "{s:?}: sim {sim} vs analytic {analytic}");
        }
    }

    #[test]
    fn input_only_moves_nothing() {
        assert_eq!(
            simulated_comm_per_core(PartitionStrategy::InputOnly, 512, 1024, 1024),
            0.0
        );
    }

    #[test]
    fn table_has_all_strategies() {
        let t = run(&Opts::fast()).unwrap();
        assert_eq!(t[0].n_rows(), 4);
    }
}
