//! Fig. 14 — PD disaggregation vs PD fusion: throughput and TBT across
//! input:output token ratios (Qwen3-4B, 64-core chip), comparing two
//! heterogeneous disaggregation configs and a homogeneous one against
//! fusion — including per-area throughput via the 7nm area model.

use crate::area;
use crate::config::{ChipConfig, ModelConfig, WorkloadConfig};
use crate::experiments::Opts;
use crate::serving::metrics::Metrics;
use crate::serving::pd_disagg::{simulate_disagg, DisaggConfig};
use crate::serving::pd_fusion::{simulate_fusion, FusionConfig};
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};

/// The compared systems: disagg homogeneous, two heterogeneous variants
/// (narrow decode array / fat decode HBM), and fusion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum System {
    DisaggHomog,
    DisaggHeteroA32H240,
    DisaggHeteroA64H480,
    Fusion,
}

impl System {
    pub fn name(&self) -> &'static str {
        match self {
            System::DisaggHomog => "disagg homog",
            System::DisaggHeteroA32H240 => "disagg A32H240",
            System::DisaggHeteroA64H480 => "disagg A64H480",
            System::Fusion => "fusion",
        }
    }

    pub fn all() -> [System; 4] {
        [
            System::DisaggHomog,
            System::DisaggHeteroA32H240,
            System::DisaggHeteroA64H480,
            System::Fusion,
        ]
    }
}

pub fn run_system(
    model: &ModelConfig,
    w: &WorkloadConfig,
    sys: System,
) -> anyhow::Result<(Metrics, f64)> {
    let mk_hetero = |sa: u64, hbm: f64| {
        let mut d = ChipConfig::large_core().core;
        d.sa_dim = sa;
        d.hbm_bw_gbps = hbm;
        ChipConfig::large_core().with_decode_core(d)
    };
    let (chip_cfg, n_decode) = match sys {
        System::DisaggHomog => (ChipConfig::large_core(), 21),
        System::DisaggHeteroA32H240 => (mk_hetero(32, 240.0), 21),
        System::DisaggHeteroA64H480 => (mk_hetero(64, 480.0), 21),
        System::Fusion => (ChipConfig::large_core(), 0),
    };
    let area = area::chip_area_mm2(&chip_cfg, n_decode);
    let mut chip = ChipSim::new(chip_cfg);
    let m = match sys {
        // §4.3.2: fusion adopts TP for both stages (PP would re-stream
        // weights per microbatch during decode) — TP=16 over the 64-core
        // chip gives 4 data-parallel fused groups.
        System::Fusion => simulate_fusion(
            &mut chip,
            model,
            w,
            &FusionConfig {
                tp: 16,
                stages: 1,
                ..FusionConfig::default()
            },
        )?,
        _ => simulate_disagg(&mut chip, model, w, &DisaggConfig::ratio_64(42, 21, 6))?,
    };
    Ok((m, area))
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let model = ModelConfig::qwen3_4b();
    let n = opts.pick(16, 3);
    // input:output ratios from decode-heavy (0.25) to prefill-heavy (10).
    let ratios: Vec<(usize, usize)> = if opts.fast {
        vec![(50, 200), (500, 50)]
    } else {
        vec![(128, 512), (256, 256), (512, 256), (1024, 256), (1000, 100)]
    };

    let mut tput = Table::new(
        "Fig 14a — throughput (tok/s) and tok/s/mm², PD disagg vs fusion (Qwen3-4B, 64 cores)",
        &["in:out", "system", "tok/s", "tok/s/mm2"],
    );
    let mut tbt = Table::new(
        "Fig 14b — TBT (ms), PD disagg vs fusion",
        &["in:out", "system", "TBT (ms)"],
    );
    for &(i, o) in &ratios {
        let w = WorkloadConfig::fixed_ratio(i, o, n);
        for sys in System::all() {
            let (m, area) = run_system(&model, &w, sys)?;
            tput.row(&[
                format!("{i}:{o}"),
                sys.name().to_string(),
                f3(m.tokens_per_s()),
                f3(m.tokens_per_s() / area * 1000.0),
            ]);
            tbt.row(&[
                format!("{i}:{o}"),
                sys.name().to_string(),
                f3(m.tbt_s().mean() * 1e3),
            ]);
        }
    }
    Ok(vec![tput, tbt])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fusion_wins_decode_heavy_throughput() {
        // Paper: at in:out < 1 fusion delivers >2.3x disagg throughput
        // (disagg's prefill cores idle during decode-heavy phases).
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(64, 256, 6);
        let (fusion, _) = run_system(&model, &w, System::Fusion).unwrap();
        let (disagg, _) = run_system(&model, &w, System::DisaggHomog).unwrap();
        assert!(
            fusion.tokens_per_s() > disagg.tokens_per_s(),
            "fusion {} vs disagg {}",
            fusion.tokens_per_s(),
            disagg.tokens_per_s()
        );
    }

    #[test]
    fn disagg_tbt_stays_stable_across_ratios() {
        // Paper: disagg TBT is stable; fusion TBT inflates as prefill
        // chunks interleave with decoding.
        let model = ModelConfig::qwen3_4b();
        let w_dec = WorkloadConfig::fixed_ratio(64, 128, 4);
        let w_pre = WorkloadConfig::fixed_ratio(1024, 64, 4);
        let (d1, _) = run_system(&model, &w_dec, System::DisaggHomog).unwrap();
        let (d2, _) = run_system(&model, &w_pre, System::DisaggHomog).unwrap();
        let ratio = d2.tbt_s().mean() / d1.tbt_s().mean();
        assert!(ratio > 0.4 && ratio < 2.5, "disagg TBT unstable: {ratio}");
    }

    #[test]
    fn table_shape() {
        let tables = run(&Opts::fast()).unwrap();
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].n_rows(), 8);
    }
}
