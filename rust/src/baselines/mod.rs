//! SOTA baseline strategy presets (Table 1): T10, WaferLLM and WSC-LLM,
//! re-expressed in this simulator's vocabulary so the §5.4 headline
//! comparison ("1.32x–6.03x over SOTA") runs both sides through identical
//! machinery — only the *strategy choices* differ.

use crate::parallel::partition::PartitionStrategy;
use crate::parallel::pd_placement::PdPlacementPolicy;
use crate::parallel::placement::Placement;

/// A named bundle of serving-strategy choices.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StrategyPreset {
    pub name: &'static str,
    /// GEMM partition used for all layers.
    pub partition: PartitionStrategy,
    /// Core placement within a TP group.
    pub placement: Placement,
    /// PD-disaggregation placement policy (None = no disaggregation).
    pub pd_policy: Option<PdPlacementPolicy>,
    /// Whether the preset can use HBM for KV/weights (SRAM-only designs
    /// offload to peer cores instead).
    pub uses_hbm: bool,
}

/// T10 (SOSP'24, targets Graphcore IPU): AllGather "rotating tensor"
/// GEMM, linear core order following core index, SRAM-only.
pub fn t10() -> StrategyPreset {
    StrategyPreset {
        name: "t10",
        partition: PartitionStrategy::OneDimMN,
        placement: Placement::LinearSeq,
        pd_policy: None,
        uses_hbm: false,
    }
}

/// WaferLLM (targets Cerebras WSE): AllGather GEMM with the interleaved
/// linear placement bounding logical-neighbour hops to ≤2, SRAM-only.
pub fn wafer_llm() -> StrategyPreset {
    StrategyPreset {
        name: "waferllm",
        partition: PartitionStrategy::OneDimMN,
        placement: Placement::LinearInterleave,
        pd_policy: None,
        uses_hbm: false,
    }
}

/// WSC-LLM (ISCA'25, wafer-scale chips): AllReduce GEMM on a 2D mesh with
/// HBM, DP-prioritized PD disaggregation.
pub fn wsc_llm() -> StrategyPreset {
    StrategyPreset {
        name: "wsc-llm",
        partition: PartitionStrategy::OneDimK,
        placement: Placement::Mesh2D,
        pd_policy: Some(PdPlacementPolicy::DpPrioritized { dp: 4 }),
        uses_hbm: true,
    }
}

/// This paper's strategy: per-scenario partition (AllReduce for short
/// sequences, AllGather/2-D for long), ring placement, PP-prioritized
/// heterogeneous PD disaggregation or PD fusion by workload.
pub fn ours(seq_len: u64, hidden: u64, tp: usize) -> StrategyPreset {
    let partition = if 2 * seq_len < hidden {
        PartitionStrategy::OneDimK
    } else if tp >= 8 {
        let rows = (1..=tp).rev().find(|r| tp % r == 0 && r * r <= tp).unwrap_or(1);
        PartitionStrategy::TwoDim { rows, cols: tp / rows }
    } else {
        PartitionStrategy::OneDimMN
    };
    StrategyPreset {
        name: "ours",
        partition,
        placement: Placement::Ring,
        pd_policy: Some(PdPlacementPolicy::PpPrioritized),
        uses_hbm: true,
    }
}

/// All SOTA baselines for sweep loops.
pub fn all_baselines() -> [StrategyPreset; 3] {
    [t10(), wafer_llm(), wsc_llm()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table1() {
        assert_eq!(t10().partition, PartitionStrategy::OneDimMN);
        assert_eq!(t10().placement, Placement::LinearSeq);
        assert!(!t10().uses_hbm);
        assert_eq!(wafer_llm().placement, Placement::LinearInterleave);
        assert_eq!(wsc_llm().partition, PartitionStrategy::OneDimK);
        assert!(wsc_llm().uses_hbm);
        assert!(matches!(
            wsc_llm().pd_policy,
            Some(PdPlacementPolicy::DpPrioritized { .. })
        ));
    }

    #[test]
    fn ours_adapts_to_sequence_length() {
        assert_eq!(ours(256, 2560, 4).partition, PartitionStrategy::OneDimK);
        assert_eq!(ours(8192, 2560, 4).partition, PartitionStrategy::OneDimMN);
        assert!(matches!(
            ours(8192, 2560, 16).partition,
            PartitionStrategy::TwoDim { rows: 4, cols: 4 }
        ));
        assert_eq!(ours(256, 2560, 4).placement, Placement::Ring);
    }
}
