//! Lightweight inter-chip interconnect cost model.
//!
//! The multi-chip cluster layer (`serving::cluster`) connects N
//! independent [`super::chip::ChipSim`]s through a chip-to-chip fabric —
//! think PCIe/CXL or a scale-out serdes link: one to two orders of
//! magnitude less bandwidth than the on-chip NoC, plus a fixed per-hop
//! latency. Cross-chip KV migration (prefix-hit-aware routing) is charged
//! against this model.
//!
//! The model is intentionally simpler than the on-chip NoC: each chip has
//! one egress port modelled as a busy-interval [`Timeline`], so
//! simultaneous migrations out of the same chip serialise (bandwidth
//! contention) while transfers from different chips proceed in parallel.
//! The switch fabric itself is assumed non-blocking — the per-chip serdes
//! is the bottleneck in practice.

use crate::sim::engine::Timeline;
use crate::util::units::{gbps_to_bytes_per_cycle, Cycle};

/// Inter-chip fabric parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InterconnectConfig {
    /// One-way base latency per transfer in microseconds (serdes + switch
    /// traversal), independent of size.
    pub latency_us: f64,
    /// Per-chip egress bandwidth in GB/s.
    pub bw_gbps: f64,
}

impl Default for InterconnectConfig {
    fn default() -> Self {
        // PCIe5 x16-class chip-to-chip link: ~64 GB/s, ~2 us one way —
        // far below the 128 GB/s on-chip NoC links, far above recompute.
        InterconnectConfig {
            latency_us: 2.0,
            bw_gbps: 64.0,
        }
    }
}

impl InterconnectConfig {
    /// Analytic contention-free transfer time in seconds: base latency
    /// plus serialisation at nominal bandwidth. Used by the fleet planner
    /// to price prefill→decode KV handoffs without building a fabric.
    pub fn transfer_s(&self, bytes: u64) -> f64 {
        self.latency_us * 1e-6 + bytes as f64 / (self.bw_gbps.max(1e-9) * 1e9)
    }
}

/// Aggregate fabric statistics for one cluster run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct InterconnectStats {
    pub transfers: u64,
    pub bytes: u64,
    /// Total egress serialisation cycles.
    pub busy_cycles: Cycle,
    /// Cycles transfers waited behind earlier ones on the same egress port.
    pub contention_cycles: Cycle,
}

/// The fabric: one egress timeline per chip.
#[derive(Debug)]
pub struct Interconnect {
    cfg: InterconnectConfig,
    latency_cycles: Cycle,
    /// `1 / egress bytes-per-cycle` (hoisted division, like the NoC).
    inv_bytes_per_cycle: f64,
    egress: Vec<Timeline>,
    /// Per-source bandwidth degradation factor in `(0, 1]` (fault
    /// injection: a flaky serdes link runs at `factor` × nominal). `1.0`
    /// — the default — divides serialisation by exactly 1, so the
    /// fault-free path is bit-identical.
    degrade: Vec<f64>,
    stats: InterconnectStats,
}

impl Interconnect {
    /// Build a fabric for `n_chips` chips clocked at `freq_mhz` (cycle
    /// accounting shares the chips' clock domain).
    pub fn new(cfg: InterconnectConfig, n_chips: usize, freq_mhz: f64) -> Self {
        let bpc = gbps_to_bytes_per_cycle(cfg.bw_gbps, freq_mhz);
        Interconnect {
            cfg,
            // 1 us at `freq_mhz` MHz is exactly `freq_mhz` cycles.
            latency_cycles: (cfg.latency_us * freq_mhz).round() as Cycle,
            inv_bytes_per_cycle: if bpc > 0.0 { 1.0 / bpc } else { 0.0 },
            egress: vec![Timeline::new(); n_chips],
            degrade: vec![1.0; n_chips],
            stats: InterconnectStats::default(),
        }
    }

    /// Degrade (or restore, with `1.0`) chip `src`'s egress bandwidth.
    pub fn set_degrade(&mut self, src: usize, factor: f64) {
        assert!(factor > 0.0 && factor <= 1.0, "degrade factor {factor}");
        self.degrade[src] = factor;
    }

    pub fn config(&self) -> InterconnectConfig {
        self.cfg
    }

    /// Serialisation cycles for `bytes` on one egress port at `factor` ×
    /// nominal bandwidth.
    fn ser_cycles_at(&self, bytes: u64, factor: f64) -> Cycle {
        let x = bytes as f64 * self.inv_bytes_per_cycle / factor;
        let t = x as Cycle;
        (t + u64::from((t as f64) < x)).max(1)
    }

    /// Serialisation cycles for `bytes` at nominal bandwidth.
    fn ser_cycles(&self, bytes: u64) -> Cycle {
        self.ser_cycles_at(bytes, 1.0)
    }

    /// Move `bytes` from chip `src` to chip `dst`, issued no earlier than
    /// `earliest`; returns the cycle the last byte lands at `dst`.
    /// Same-chip or empty transfers are free.
    pub fn transfer(&mut self, src: usize, dst: usize, bytes: u64, earliest: Cycle) -> Cycle {
        if src == dst || bytes == 0 {
            return earliest;
        }
        let ser = self.ser_cycles_at(bytes, self.degrade[src]);
        let start = self.egress[src].reserve(earliest, ser);
        self.stats.transfers += 1;
        self.stats.bytes += bytes;
        self.stats.busy_cycles += ser;
        self.stats.contention_cycles += start - earliest;
        start + ser + self.latency_cycles
    }

    /// Uncontended landing estimate for `bytes` issued at `earliest`,
    /// without reserving egress time (planning probes).
    pub fn estimate(&self, bytes: u64, earliest: Cycle) -> Cycle {
        if bytes == 0 {
            return earliest;
        }
        earliest + self.ser_cycles(bytes) + self.latency_cycles
    }

    pub fn stats(&self) -> InterconnectStats {
        self.stats
    }

    pub fn reset(&mut self) {
        for e in &mut self.egress {
            e.reset();
        }
        for d in &mut self.degrade {
            *d = 1.0;
        }
        self.stats = InterconnectStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric() -> Interconnect {
        // 64 GB/s at 500 MHz = 128 B/cycle; 2 us = 1000 cycles latency.
        Interconnect::new(InterconnectConfig::default(), 4, 500.0)
    }

    #[test]
    fn uncontended_transfer_is_latency_plus_serialisation() {
        let mut f = fabric();
        // 128_000 bytes / 128 B/cyc = 1000 ser cycles + 1000 latency.
        let landing = f.transfer(0, 1, 128_000, 500);
        assert_eq!(landing, 500 + 1000 + 1000);
        assert_eq!(f.stats().transfers, 1);
        assert_eq!(f.stats().bytes, 128_000);
        assert_eq!(f.stats().contention_cycles, 0);
    }

    #[test]
    fn same_chip_and_empty_transfers_are_free() {
        let mut f = fabric();
        assert_eq!(f.transfer(2, 2, 1 << 20, 77), 77);
        assert_eq!(f.transfer(0, 1, 0, 77), 77);
        assert_eq!(f.stats().transfers, 0);
    }

    #[test]
    fn same_source_egress_serialises() {
        let mut f = fabric();
        let a = f.transfer(0, 1, 128_000, 0);
        let b = f.transfer(0, 2, 128_000, 0);
        // Second transfer waits for the first to clear the egress port.
        assert_eq!(b, a + 1000);
        assert_eq!(f.stats().contention_cycles, 1000);
    }

    #[test]
    fn different_sources_do_not_contend() {
        let mut f = fabric();
        let a = f.transfer(0, 2, 128_000, 0);
        let b = f.transfer(1, 2, 128_000, 0);
        assert_eq!(a, b);
        assert_eq!(f.stats().contention_cycles, 0);
    }

    #[test]
    fn estimate_matches_uncontended_transfer() {
        let mut f = fabric();
        let est = f.estimate(64_000, 123);
        assert_eq!(f.transfer(3, 0, 64_000, 123), est);
    }

    #[test]
    fn degraded_source_serialises_slower_and_restores_exactly() {
        let mut f = fabric();
        f.set_degrade(0, 0.25); // quarter bandwidth: 4x serialisation.
        assert_eq!(f.transfer(0, 1, 128_000, 0), 4000 + 1000);
        // Other sources are unaffected.
        assert_eq!(f.transfer(1, 2, 128_000, 0), 1000 + 1000);
        f.set_degrade(0, 1.0);
        let mut clean = fabric();
        assert_eq!(
            f.transfer(0, 2, 128_000, 10_000),
            clean.transfer(0, 2, 128_000, 10_000),
            "restored link must be bit-exact once its backlog clears"
        );
    }

    #[test]
    fn analytic_transfer_s_matches_fabric_cycles() {
        // 128_000 B at 64 GB/s = 2 us serialisation + 2 us latency = 4 us;
        // at 500 MHz that is the fabric's 2000 cycles.
        let cfg = InterconnectConfig::default();
        let s = cfg.transfer_s(128_000);
        assert!((s * 500e6 - 2000.0).abs() < 1e-6, "s={s}");
    }

    #[test]
    fn reset_clears_ports_and_stats() {
        let mut f = fabric();
        f.transfer(0, 1, 1 << 20, 0);
        f.reset();
        assert_eq!(f.stats(), InterconnectStats::default());
        assert_eq!(f.transfer(0, 1, 128_000, 0), 2000);
    }
}
