//! Hierarchical memory management (§4.2): multi-grained KV cache across
//! SRAM and HBM, plus the SRAM budget planner.
//!
//! The paper's scheme (Fig. 5):
//!
//! - **SRAM** is scarce, so the KV cache living there is managed
//!   *fine-grained*, at **block** granularity — a request's KV tensor is a
//!   linked list of (possibly non-contiguous) block IDs, and a free-block
//!   list recycles blocks as requests retire ([`blocks`]).
//! - **HBM** is plentiful and strongly prefers sequential access, so
//!   spilled KV is managed *coarse-grained*: one whole max-length buffer
//!   per request, organised as a **ring buffer** ([`ring`]).
//! - [`kv`] combines both: appends go to SRAM while blocks remain, then
//!   spill to the request's HBM buffer; per-request SRAM/HBM residency is
//!   what the attention operator uses to charge HBM streaming time.
//! - [`planner`] computes the SRAM budget split between activations,
//!   communication staging, temporaries, KV blocks, and resident weights
//!   (in that priority order — §4.2 "weight and activation management").

pub mod blocks;
pub mod kv;
pub mod planner;
pub mod ring;

pub use blocks::BlockAllocator;
pub use kv::{KvCache, KvResidency};
pub use planner::SramPlan;
pub use ring::RingBuffer;
