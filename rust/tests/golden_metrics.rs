//! Golden regression: fixed-seed serving runs must be byte-stable.
//!
//! Two layers of pinning:
//!
//! 1. The `util::rng` generator itself is pinned against hard-coded
//!    reference values (computed independently from the xoshiro256** +
//!    SplitMix64 definition), so a silent RNG change cannot re-seed every
//!    "deterministic" trace while the within-run comparisons still pass.
//! 2. Fixed-seed fusion / disagg / hybrid runs on `qwen3_4b` are rendered
//!    to a canonical text summary and compared byte-for-byte across two
//!    independent simulations (fresh chip, fresh scheduler each time).

use npusim::config::{ArrivalProcess, ChipConfig, ModelConfig, PriorityMix, WorkloadConfig};
use npusim::serving::metrics::Metrics;
use npusim::serving::pd_disagg::DisaggConfig;
use npusim::serving::pd_fusion::FusionConfig;
use npusim::serving::request::{self, Prefix, Priority, Request};
use npusim::serving::scheduler::{self, HybridConfig, SchedulerConfig};
use npusim::sim::chip::ChipSim;
use npusim::util::rng::Rng;
use std::fmt::Write as _;

#[test]
fn rng_stream_matches_reference_values() {
    // First four xoshiro256** outputs for the workload seed used by every
    // preset (2025) and for the property-test base seed (0xA5A5), computed
    // out-of-band from the generator definition.
    let mut r = Rng::new(2025);
    assert_eq!(r.next_u64(), 0xC9FC_BF65_C046_112F);
    assert_eq!(r.next_u64(), 0x7B7B_3399_E150_A198);
    assert_eq!(r.next_u64(), 0x68F6_F146_F11E_19C1);
    assert_eq!(r.next_u64(), 0x8F60_5909_BBB6_33B2);

    let mut r = Rng::new(0xA5A5);
    assert_eq!(r.next_u64(), 0xFE8F_49D9_C1CD_F208);
    assert_eq!(r.next_u64(), 0x4381_7C21_E0AE_2B2A);
    assert_eq!(r.next_u64(), 0xBE67_4453_B7AF_0359);
    assert_eq!(r.next_u64(), 0x3988_9EE4_1422_EED3);
}

/// Canonical text rendering of a metrics object: every integer field of
/// every record (sorted by request id) plus the makespan. Any cycle-level
/// drift shows up as a byte diff.
fn summarize(m: &Metrics) -> String {
    let mut records: Vec<_> = m.records().to_vec();
    records.sort_by_key(|r| r.id);
    let mut out = String::new();
    let _ = writeln!(out, "n={} makespan={}", m.n_requests(), m.makespan());
    for r in records {
        let _ = writeln!(
            out,
            "id={} arrival={} first={} finish={} in={} out={}",
            r.id, r.arrival, r.first_token, r.finish, r.input_tokens, r.output_tokens
        );
    }
    out
}

fn run_once(sys: &SchedulerConfig, w: &WorkloadConfig) -> String {
    let model = ModelConfig::qwen3_4b();
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let mut sched = sys.build();
    let m = scheduler::simulate(&mut chip, &model, w, sched.as_mut())
        .unwrap_or_else(|e| panic!("{} failed: {e:#}", sys.name()));
    summarize(&m)
}

#[test]
fn fixed_seed_runs_are_byte_stable_across_runs() {
    // One decode-leaning and one prefill-leaning fixed-seed workload; the
    // same seed must reproduce the same per-request cycle timeline for all
    // three schedulers.
    let workloads = [
        WorkloadConfig::fixed_ratio(256, 24, 6).with_seed(7),
        WorkloadConfig::sharegpt_like(5).with_seed(11),
    ];
    let systems = [
        SchedulerConfig::Fusion(FusionConfig::default()),
        SchedulerConfig::Disagg(DisaggConfig::p42_d21()),
        SchedulerConfig::Hybrid(HybridConfig {
            // Aggressive controller so the adaptive path itself (not just
            // the quiescent fusion-equivalent path) is pinned.
            window: 8,
            hysteresis: 1,
            min_dwell: 8,
            ..HybridConfig::default()
        }),
    ];
    for w in &workloads {
        for sys in &systems {
            let a = run_once(sys, w);
            let b = run_once(sys, w);
            assert!(!a.is_empty());
            assert_eq!(
                a,
                b,
                "{} on {} is not deterministic across runs",
                sys.name(),
                w.name
            );
        }
    }
}

#[test]
fn different_seeds_change_the_timeline() {
    // Guards against the summary being insensitive (e.g. constant output).
    let sys = SchedulerConfig::Fusion(FusionConfig::default());
    let a = run_once(&sys, &WorkloadConfig::sharegpt_like(4).with_seed(1));
    let b = run_once(&sys, &WorkloadConfig::sharegpt_like(4).with_seed(2));
    assert_ne!(a, b);
}

#[test]
fn prefix_cache_and_memo_are_off_by_default() {
    // The golden vectors above pin the *default* configurations: every
    // opt-in feature must stay opt-in for those vectors to stay
    // meaningful — including the two-tier cache and cross-pipe sharing.
    let f = FusionConfig::default();
    assert!(!f.prefix_cache && !f.memo);
    assert!(!f.hbm_tier && !f.cross_pipe);
    let d = DisaggConfig::default();
    assert!(!d.prefix_cache && !d.memo);
    assert!(!d.hbm_tier && !d.cross_pipe);
}

#[test]
fn enabling_the_prefix_cache_is_inert_without_shared_prefixes() {
    // With no shareable tokens in the trace, cache-on must reproduce the
    // cache-off timeline byte-for-byte (the machinery only changes
    // behaviour when something matches or registers).
    for w in [
        WorkloadConfig::fixed_ratio(256, 24, 6).with_seed(7),
        WorkloadConfig::sharegpt_like(5).with_seed(11),
    ] {
        let off = run_once(&SchedulerConfig::Fusion(FusionConfig::default()), &w);
        let on = run_once(
            &SchedulerConfig::Fusion(FusionConfig {
                prefix_cache: true,
                ..FusionConfig::default()
            }),
            &w,
        );
        assert_eq!(off, on, "prefix-cache machinery perturbed {}", w.name);
    }
}

#[test]
fn shared_prefix_runs_are_byte_stable_and_cache_changes_the_timeline() {
    // Golden determinism vector for the prefix-cache feature itself: the
    // shared-prefix trace under every scheduler, cache on, twice.
    let w = WorkloadConfig::shared_prefix(8).with_seed(13);
    let systems = [
        SchedulerConfig::Fusion(FusionConfig {
            prefix_cache: true,
            ..FusionConfig::default()
        }),
        SchedulerConfig::Disagg(DisaggConfig {
            prefix_cache: true,
            ..DisaggConfig::p42_d21()
        }),
        SchedulerConfig::Hybrid(HybridConfig {
            fusion: FusionConfig {
                prefix_cache: true,
                ..FusionConfig::default()
            },
            ..HybridConfig::default()
        }),
    ];
    for sys in &systems {
        let a = run_once(sys, &w);
        let b = run_once(sys, &w);
        assert_eq!(a, b, "{} shared-prefix run not deterministic", sys.name());
    }
    // And the cache must actually move the needle on this trace.
    let off = run_once(&SchedulerConfig::Fusion(FusionConfig::default()), &w);
    let on = run_once(&systems[0], &w);
    assert_ne!(off, on, "prefix cache had no effect on a shared trace");
}

#[test]
fn hbm_tier_and_cross_pipe_off_pin_single_tier_behaviour() {
    // The tier golden vector: with `--hbm-tier --cross-pipe` off, the
    // prefix-cache-on timeline must be bit-identical to the pre-tier
    // implementation — and, since the tier only acts at the eviction
    // point, enabling `hbm_tier` on a pressure-free shared trace must
    // also reproduce it bit-for-bit.
    let w = WorkloadConfig::shared_prefix(8).with_seed(13);
    let single_tier = run_once(
        &SchedulerConfig::Fusion(FusionConfig {
            prefix_cache: true,
            ..FusionConfig::default()
        }),
        &w,
    );
    // Byte-stable across runs (the vector itself).
    assert_eq!(
        single_tier,
        run_once(
            &SchedulerConfig::Fusion(FusionConfig {
                prefix_cache: true,
                ..FusionConfig::default()
            }),
            &w,
        )
    );
    // The tier only acts at the eviction point: without evictions it is
    // bit-inert; with evictions it must be demoting instead.
    let run_metrics = |cfg: FusionConfig| {
        let model = ModelConfig::qwen3_4b();
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut sched = SchedulerConfig::Fusion(cfg).build();
        scheduler::simulate(&mut chip, &model, &w, sched.as_mut()).unwrap()
    };
    let off = run_metrics(FusionConfig {
        prefix_cache: true,
        ..FusionConfig::default()
    });
    let on = run_metrics(FusionConfig {
        prefix_cache: true,
        hbm_tier: true,
        ..FusionConfig::default()
    });
    if off.cache.prefix_evictions == 0 {
        assert_eq!(
            single_tier,
            summarize(&on),
            "hbm_tier perturbed an eviction-free run"
        );
        assert_eq!(on.cache.tier_demotions, 0);
    } else {
        assert!(
            on.cache.tier_demotions > 0,
            "pressure evicted {} blocks but the tier never demoted",
            off.cache.prefix_evictions
        );
        assert_eq!(on.cache.prefix_evictions, 0, "tier must demote, not drop");
    }
}

#[test]
fn two_tier_cross_pipe_runs_are_deterministic() {
    // The feature-on golden vector: the full two-tier + cross-pipe
    // configuration must be byte-stable across runs on the pressured
    // streamed path (the one-chip cluster driver, where affinity routing
    // actually sees warm caches).
    use npusim::experiments::{tier_study, Opts};
    let a = tier_study::bench_rows(&Opts::fast()).unwrap();
    let b = tier_study::bench_rows(&Opts::fast()).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.tokens_skipped, y.tokens_skipped, "{}", x.config);
        assert_eq!(x.promotions, y.promotions, "{}", x.config);
        assert_eq!(x.noc_imports, y.noc_imports, "{}", x.config);
        assert_eq!(x.ttft_p99_s, y.ttft_p99_s, "{}", x.config);
    }
}

#[test]
fn memoized_runs_are_deterministic() {
    let w = WorkloadConfig::fixed_ratio(256, 24, 6).with_seed(7);
    let sys = SchedulerConfig::Fusion(FusionConfig {
        memo: true,
        ..FusionConfig::default()
    });
    assert_eq!(run_once(&sys, &w), run_once(&sys, &w));
}

#[test]
fn uniform_priority_mix_and_default_flags_stay_bit_identical() {
    // The control-plane features are strictly opt-in: a default
    // (all-normal) priority mix draws no extra randomness, so the golden
    // vectors above stay pinned, and making the default explicit changes
    // nothing either.
    let base = WorkloadConfig::sharegpt_like(5).with_seed(11);
    let explicit = base.clone().with_priority_mix(PriorityMix::default());
    assert_eq!(request::generate(&base), request::generate(&explicit));
    assert!(request::generate(&base)
        .iter()
        .all(|r| r.priority == Priority::Normal));
    let sys = SchedulerConfig::Fusion(FusionConfig::default());
    assert_eq!(run_once(&sys, &base), run_once(&sys, &explicit));
}

#[test]
fn priority_and_flash_crowd_runs_are_byte_stable() {
    // The feature-on golden vector: a flash-crowd arrival process with a
    // mixed priority population, replayed under every scheduler, must be
    // byte-stable across independent simulations.
    let w = WorkloadConfig::sharegpt_like(8)
        .with_seed(17)
        .with_arrival(ArrivalProcess::FlashCrowd {
            base_rate: 2.0,
            peak_rate: 200.0,
            spike_start_s: 0.2,
            spike_len_s: 1.0,
        })
        .with_priority_mix(PriorityMix {
            high: 0.25,
            low: 0.25,
        });
    // The trace itself is deterministic and actually mixed.
    let reqs = request::generate(&w);
    assert_eq!(reqs, request::generate(&w));
    assert!(reqs.iter().any(|r| r.priority != Priority::Normal));
    let systems = [
        SchedulerConfig::Fusion(FusionConfig {
            max_batch: 2,
            ..FusionConfig::default()
        }),
        SchedulerConfig::Disagg(DisaggConfig::p42_d21()),
        SchedulerConfig::Hybrid(HybridConfig::default()),
    ];
    for sys in &systems {
        assert_eq!(
            run_once(sys, &w),
            run_once(sys, &w),
            "{} priority run not deterministic",
            sys.name()
        );
    }
}

#[test]
fn priorities_reorder_a_contended_timeline() {
    // Guards against the priority plumbing being dead code: on a fully
    // serialized pipe (max_batch 1, co-arriving requests) a high-priority
    // straggler must jump the queue, so the flattened-priority timeline
    // must differ.
    let mk = |classes: &[Priority]| -> Vec<Request> {
        classes
            .iter()
            .enumerate()
            .map(|(i, &priority)| Request {
                id: i as u64,
                arrival_s: 0.0,
                input_len: 64 + 16 * i,
                output_len: 4,
                prefix: Prefix::default(),
                priority,
            })
            .collect()
    };
    let run = |reqs: Vec<Request>| {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut sched = SchedulerConfig::Fusion(FusionConfig {
            tp: 16,
            stages: 4,
            max_batch: 1,
            ..FusionConfig::default()
        })
        .build();
        let m = scheduler::simulate_requests(
            &mut chip,
            &ModelConfig::qwen3_4b(),
            reqs,
            sched.as_mut(),
        )
        .unwrap();
        summarize(&m)
    };
    use Priority::{High, Low, Normal};
    let mixed = run(mk(&[Low, Normal, Low, High]));
    let flat = run(mk(&[Normal, Normal, Normal, Normal]));
    assert_ne!(mixed, flat, "priorities never changed the schedule");
    // And the mixed ordering itself is stable.
    assert_eq!(mixed, run(mk(&[Low, Normal, Low, High])));
}
