//! Cluster-layer regression tests: golden determinism vectors for every
//! router policy (pinned RNG workloads), and conservation properties —
//! every admitted request completes on exactly one chip, and the
//! aggregate rollup neither loses nor invents tokens.

use npusim::config::{ChipConfig, ModelConfig, PrefixSharing, WorkloadConfig};
use npusim::serving::cluster::{self, ClusterConfig, ClusterMetrics, RouterPolicy};
use npusim::serving::pd_disagg::DisaggConfig;
use npusim::serving::pd_fusion::FusionConfig;
use npusim::serving::request;
use npusim::serving::scheduler::{HybridConfig, SchedulerConfig};
use std::collections::HashSet;
use std::fmt::Write as _;

fn shared_workload(n: usize, seed: u64) -> WorkloadConfig {
    WorkloadConfig::shared_prefix(n)
        .with_seed(seed)
        .with_prefix(PrefixSharing {
            n_groups: 2,
            shared_prefix_len: 384,
            turns: 2,
            think_time_s: 1.0,
        })
}

fn fusion_cached() -> SchedulerConfig {
    SchedulerConfig::Fusion(FusionConfig {
        prefix_cache: true,
        ..FusionConfig::default()
    })
}

fn run_cluster(
    sched: SchedulerConfig,
    router: RouterPolicy,
    chips: usize,
    w: &WorkloadConfig,
) -> ClusterMetrics {
    let cfg = ClusterConfig::new(ChipConfig::large_core(), chips, sched, router);
    cluster::simulate_cluster(&cfg, &ModelConfig::qwen3_4b(), w)
        .unwrap_or_else(|e| panic!("{} cluster failed: {e:#}", router.name()))
}

/// Canonical text rendering: per-chip request timelines plus the routing
/// histogram — any cycle-level or routing drift shows up as a byte diff.
fn summarize(cm: &ClusterMetrics) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "routed={:?} migrations={}", cm.routed, cm.migrations);
    for (i, m) in cm.per_chip.iter().enumerate() {
        let mut records = m.records().to_vec();
        records.sort_by_key(|r| r.id);
        let _ = writeln!(out, "chip{i} n={}", m.n_requests());
        for r in records {
            let _ = writeln!(
                out,
                "  id={} arrival={} first={} finish={} in={} out={}",
                r.id, r.arrival, r.first_token, r.finish, r.input_tokens, r.output_tokens
            );
        }
    }
    out
}

#[test]
fn every_router_is_deterministic_across_runs() {
    let w = shared_workload(10, 17);
    for router in RouterPolicy::ALL {
        let a = summarize(&run_cluster(fusion_cached(), router, 2, &w));
        let b = summarize(&run_cluster(fusion_cached(), router, 2, &w));
        assert!(!a.is_empty());
        assert_eq!(a, b, "{} router not deterministic", router.name());
    }
}

#[test]
fn routers_actually_route_differently() {
    // Round-robin and least-loaded/prefix-aware must not all collapse to
    // the same placement on a skewed shared-prefix workload (guards
    // against the views being ignored).
    let w = shared_workload(12, 23);
    let rr = run_cluster(fusion_cached(), RouterPolicy::RoundRobin, 2, &w);
    let prefix = run_cluster(fusion_cached(), RouterPolicy::PrefixAware, 2, &w);
    assert_ne!(
        summarize(&rr),
        summarize(&prefix),
        "prefix-aware routing is indistinguishable from round-robin"
    );
}

#[test]
fn every_request_completes_on_exactly_one_chip() {
    // The cluster exactly-once property, across routers, schedulers and
    // chip counts: the union of per-chip completions is a permutation of
    // the request ids, and output tokens are conserved through the rollup.
    let systems = [
        fusion_cached(),
        SchedulerConfig::Disagg(DisaggConfig {
            prefix_cache: true,
            ..DisaggConfig::p42_d21()
        }),
        SchedulerConfig::Hybrid(HybridConfig {
            fusion: FusionConfig {
                prefix_cache: true,
                ..FusionConfig::default()
            },
            ..HybridConfig::default()
        }),
    ];
    for (si, sched) in systems.into_iter().enumerate() {
        for router in RouterPolicy::ALL {
            for chips in [2usize, 3] {
                let w = shared_workload(9, 31 + si as u64);
                let reqs = request::generate(&w);
                let expected_out: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
                let expected_ids: Vec<u64> = {
                    let mut ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
                    ids.sort_unstable();
                    ids
                };
                let cm = run_cluster(sched, router, chips, &w);
                // Exactly one completion per id, across all chips.
                let mut seen = HashSet::new();
                let mut ids = Vec::new();
                for m in &cm.per_chip {
                    for r in m.records() {
                        assert!(
                            seen.insert(r.id),
                            "request {} completed on more than one chip ({}, {} chips)",
                            r.id,
                            router.name(),
                            chips
                        );
                        ids.push(r.id);
                    }
                }
                ids.sort_unstable();
                assert_eq!(ids, expected_ids, "{} on {chips} chips", router.name());
                // Routing histogram accounts for every admission.
                assert_eq!(cm.routed.iter().sum::<usize>(), reqs.len());
                assert_eq!(cm.routed.len(), chips);
                // Token conservation through the rollup.
                let agg = cm.aggregate();
                let out: u64 = agg.records().iter().map(|r| r.output_tokens).sum();
                assert_eq!(out, expected_out, "{} on {chips} chips", router.name());
                let per_chip_out: u64 = cm
                    .per_chip
                    .iter()
                    .flat_map(|m| m.records())
                    .map(|r| r.output_tokens)
                    .sum();
                assert_eq!(per_chip_out, out, "rollup lost or invented tokens");
            }
        }
    }
}

#[test]
fn mixed_cluster_hit_rate_denominator_scopes_to_cache_enabled_admissions() {
    // One chip caches, one does not (a mixed cluster): the rollup's
    // hit-rate denominator must count only consultations on the
    // cache-enabled chip — admissions on the prefix-off chip (and
    // unshareable prompts anywhere) cannot dilute the rate.
    use npusim::serving::scheduler::Scheduler;
    let w = shared_workload(10, 47);
    let reqs = request::generate(&w);
    let cfg = ClusterConfig::new(
        ChipConfig::large_core(),
        2,
        fusion_cached(),
        RouterPolicy::RoundRobin,
    );
    let scheds: Vec<Box<dyn Scheduler>> = vec![
        fusion_cached().build(),
        SchedulerConfig::Fusion(FusionConfig::default()).build(), // cache off
    ];
    let cm =
        cluster::simulate_cluster_mixed(&cfg, &ModelConfig::qwen3_4b(), reqs, scheds).unwrap();
    assert_eq!(cm.n_requests(), 10);
    // Round-robin puts half the (all-shareable) requests on each chip:
    // only chip 0's admissions may count as lookups.
    let shareable_on_chip0 = cm.routed[0] as u64;
    let agg = cm.aggregate();
    assert_eq!(
        agg.cache.prefix_lookups, shareable_on_chip0,
        "hit-rate denominator must equal cache-enabled consultations"
    );
    // The cache-off chip contributes zero cache counters of any kind.
    assert_eq!(cm.per_chip[1].cache.prefix_lookups, 0);
    assert_eq!(cm.per_chip[1].cache.prefix_hits, 0);
    // And the rate is therefore internally consistent.
    assert!(agg.cache.prefix_hits <= agg.cache.prefix_lookups);
}

#[test]
fn homogeneous_fleet_spec_is_bit_identical_to_the_legacy_constructor() {
    // The FleetSpec redesign must be a pure refactor for homogeneous
    // fleets: on the pinned router-determinism vectors, a cluster built
    // through `ClusterConfig::builder(FleetSpec::homogeneous(...))` must
    // replay byte-for-byte what the legacy `(chip, n, sched, router)`
    // constructor produces — same routing histogram, same per-chip
    // cycle-level timelines.
    use npusim::serving::fleet::FleetSpec;
    let w = shared_workload(10, 17);
    let model = ModelConfig::qwen3_4b();
    for router in RouterPolicy::ALL {
        let legacy = ClusterConfig::new(ChipConfig::large_core(), 2, fusion_cached(), router);
        let fleet = ClusterConfig::builder(FleetSpec::homogeneous(
            ChipConfig::large_core(),
            2,
            fusion_cached(),
        ))
        .router(router)
        .build();
        let a = summarize(&cluster::simulate_cluster(&legacy, &model, &w).unwrap());
        let b = summarize(&cluster::simulate_cluster(&fleet, &model, &w).unwrap());
        assert!(!a.is_empty());
        assert_eq!(
            a,
            b,
            "{} router: homogeneous FleetSpec diverged from the legacy constructor",
            router.name()
        );
    }
}

#[test]
fn migrations_are_charged_on_the_interconnect() {
    // Force migration pressure: a tiny load gap and a strongly skewed
    // prefix workload. If any migration happens, interconnect bytes must
    // be non-zero (the transfer is charged, not free).
    let w = WorkloadConfig::shared_prefix(16)
        .with_seed(5)
        .with_prefix(PrefixSharing {
            n_groups: 1,
            shared_prefix_len: 512,
            turns: 2,
            think_time_s: 0.2,
        });
    let mut cfg = ClusterConfig::new(
        ChipConfig::large_core(),
        2,
        fusion_cached(),
        RouterPolicy::PrefixAware,
    );
    cfg.migrate_load_gap = 0;
    let cm = cluster::simulate_cluster(&cfg, &ModelConfig::qwen3_4b(), &w).unwrap();
    assert_eq!(cm.n_requests(), 16);
    if cm.migrations > 0 {
        assert!(cm.interconnect.transfers >= cm.migrations);
        assert!(cm.interconnect.bytes > 0, "migration moved zero bytes");
    }
}
