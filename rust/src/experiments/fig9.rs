//! Fig. 9 — TP partition strategies (MN / K / 2-D) vs input sequence
//! length: single prefill-request latency at TP=4.
//!
//! The paper's findings this regenerates: K-dimension (AllReduce) partition
//! wins while `seq < hidden` (up to 6.03x at seq 256 on Qwen3-4B) and
//! degrades sharply beyond; 2-D beats 1-D MN by ~1.44x on average.

use crate::config::{ChipConfig, ModelConfig};
use crate::experiments::Opts;
use crate::memmgr::planner::{plan, PlanRequest};
use crate::memmgr::KvCache;
use crate::model::exec::{run_iteration, ExecConfig};
use crate::model::{BatchItem, IterBatch};
use crate::parallel::partition::PartitionStrategy;
use crate::parallel::placement::{Placement, Region, TpGroup};
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};
use crate::util::units::cycles_to_ms;

/// Latency (ms) of one full-model prefill pass at TP=4 with `strategy`.
pub fn prefill_latency_ms(model: &ModelConfig, seq: u64, strategy: PartitionStrategy) -> f64 {
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let group = TpGroup::place(Region::new(0, 0, 2, 2), Placement::Ring);
    let p = plan(
        &chip.cfg.core,
        model,
        &PlanRequest {
            layers: model.layers,
            tp: 4,
            iter_tokens: seq as usize,
            kv_share: 0.5,
        },
    );
    let bpt = (model.kv_bytes_per_token_layer() * model.layers as u64 / 4).max(1);
    let mut kv = KvCache::new(p.kv_bytes, 16, chip.cfg.core.hbm_bytes, bpt, model.max_context as u64);
    kv.admit(1);
    let exec = ExecConfig::new(strategy, model.layers, true);
    let batch = IterBatch::new(vec![BatchItem::prefill(1, seq, seq)]);
    let t = run_iteration(&mut chip, &group, model, &p, &exec, &batch, &mut kv);
    cycles_to_ms(t, chip.cfg.freq_mhz)
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let models = if opts.fast {
        vec![ModelConfig::qwen3_4b()]
    } else {
        vec![ModelConfig::qwen3_4b(), ModelConfig::qwen3_8b()]
    };
    let seqs: Vec<u64> = opts.pick(vec![256, 1024, 4096, 16384], vec![256, 4096]);

    let mut tables = Vec::new();
    for model in &models {
        let mut t = Table::new(
            &format!("Fig 9 — {} prefill latency (ms) by partition strategy, TP=4", model.name),
            &["seq len", "1d-mn", "1d-k", "2d-mnk", "k/mn speedup", "2d/mn speedup"],
        );
        for &seq in &seqs {
            let mn = prefill_latency_ms(model, seq, PartitionStrategy::OneDimMN);
            let k = prefill_latency_ms(model, seq, PartitionStrategy::OneDimK);
            let d2 = prefill_latency_ms(model, seq, PartitionStrategy::TwoDim { rows: 2, cols: 2 });
            t.row(&[
                seq.to_string(),
                f3(mn),
                f3(k),
                f3(d2),
                f3(mn / k),
                f3(mn / d2),
            ]);
        }
        tables.push(t);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_partition_wins_short_sequences() {
        let m = ModelConfig::qwen3_4b();
        let mn = prefill_latency_ms(&m, 256, PartitionStrategy::OneDimMN);
        let k = prefill_latency_ms(&m, 256, PartitionStrategy::OneDimK);
        assert!(k < mn, "K {k} must beat MN {mn} at seq 256");
    }

    #[test]
    fn k_partition_degrades_long_sequences() {
        let m = ModelConfig::qwen3_4b();
        let mn = prefill_latency_ms(&m, 16384, PartitionStrategy::OneDimMN);
        let k = prefill_latency_ms(&m, 16384, PartitionStrategy::OneDimK);
        assert!(mn < k, "MN {mn} must beat K {k} at seq 16384");
    }

    #[test]
    fn crossover_near_hidden_size() {
        // The win flips somewhere between seq << hidden and seq >> hidden.
        let m = ModelConfig::qwen3_4b(); // hidden 2560
        let short_ratio = prefill_latency_ms(&m, 256, PartitionStrategy::OneDimMN)
            / prefill_latency_ms(&m, 256, PartitionStrategy::OneDimK);
        let long_ratio = prefill_latency_ms(&m, 16384, PartitionStrategy::OneDimMN)
            / prefill_latency_ms(&m, 16384, PartitionStrategy::OneDimK);
        assert!(short_ratio > 1.0 && long_ratio < 1.0);
    }

    #[test]
    fn table_shape() {
        let tables = run(&Opts::fast()).unwrap();
        assert_eq!(tables.len(), 1);
        assert_eq!(tables[0].n_rows(), 2);
    }
}
