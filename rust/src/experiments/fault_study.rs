//! `fault_study` — fleet serving under injected faults: the same steady
//! trace (Poisson at half the 4-chip fleet's sustainable rate) replayed
//! under four deterministic fault scenarios:
//!
//! - `none`           — healthy fleet baseline.
//! - `crash_recover`  — chip 0 crashes while serving its first request
//!   and never restarts; the frontend detects the crash within one
//!   heartbeat, drains the stranded requests, and retries them KV-aware
//!   on surviving chips with bounded backoff
//!   ([`RecoveryPolicy::Recover`]).
//! - `crash_resubmit` — the same crash, but the frontend does nothing:
//!   each stranded client notices only via its own timeout (set to the
//!   TTFT SLO) and resubmits from scratch
//!   ([`RecoveryPolicy::Resubmit`]) — the naive drop-and-resubmit
//!   baseline recovery must beat.
//! - `degrade`        — no crash: one chip's outbound links at 0.4x
//!   bandwidth and another chip's HBM at 0.5x for a mid-trace window;
//!   degraded chips advertise proportionally shrunk capacity so the
//!   least-loaded router steers around them.
//!
//! The gated acceptance properties (`BENCH_serving.json` `"fault"`
//! section, checked by `tools/bench_check`):
//!
//! 1. **Exactly-once**: `completed + shed == offered` in every scenario —
//!    a crash strands nothing and duplicates nothing.
//! 2. **Recovery beats resubmission**: `crash_recover` goodput-under-SLO
//!    strictly exceeds `crash_resubmit`'s (frontend-driven retry-with-
//!    backoff re-admits stranded work within milliseconds of detection;
//!    a client timeout burns a whole SLO budget first).
//! 3. **Bounded degradation**: losing 1 of N chips costs at most
//!    `2/N + 0.35` of the healthy goodput (capacity share plus detect /
//!    re-prefill / queue-shuffle overhead).
//!
//! ```sh
//! cargo run --release -p npusim -- experiment fault_study
//! ```

use crate::config::{ArrivalProcess, ChipConfig, LenDist, ModelConfig, WorkloadConfig};
use crate::experiments::{overload_study, Opts};
use crate::serving::cluster::{self, ClusterConfig, RouterPolicy};
use crate::serving::faults::{FaultEvent, FaultKind, FaultSchedule, RecoveryPolicy};
use crate::serving::pd_fusion::FusionConfig;
use crate::serving::request::{self, Request};
use crate::serving::scheduler::SchedulerConfig;
use crate::util::table::{f3, Table};

/// Fleet size of the study — large enough that one chip is a 25% capacity
/// share and the `2/N` degradation bound is a real constraint.
pub const FAULT_CHIPS: usize = 4;

/// One fault-scenario cell.
#[derive(Debug, Clone)]
pub struct FaultRun {
    pub scenario: &'static str,
    pub chips: usize,
    pub offered: usize,
    pub completed: usize,
    pub shed: u64,
    pub crashes: u64,
    pub restarts: u64,
    pub degradations: u64,
    /// Stranded requests the frontend re-admitted (first retry each).
    pub recovered: u64,
    pub retries: u64,
    /// Recovery retries that exhausted their budget and were shed.
    pub recovery_shed: u64,
    pub tokens_recomputed: u64,
    pub tokens_restored: u64,
    /// Mean crash-to-detection latency (seconds; heartbeat-bounded).
    pub mean_detect_s: f64,
    pub slo_ttft_s: f64,
    pub goodput_tok_s: f64,
    pub tok_s: f64,
}

/// Per-chip scheduler: one chip-wide fused pipeline (as in
/// `overload_study`), so each chip's queue maps 1:1 onto its probes.
fn fleet_sched() -> SchedulerConfig {
    SchedulerConfig::Fusion(FusionConfig {
        tp: 16,
        stages: 4,
        ..FusionConfig::default()
    })
}

/// The steady trace of the study: Poisson arrivals at `rate`, lengths in
/// the overload-study band.
fn fault_trace(n: usize, rate: f64) -> Vec<Request> {
    let mut w = WorkloadConfig::fixed_ratio(384, 1, n);
    w.name = "fault".into();
    w.input_len = LenDist::Uniform(256, 512);
    w.output_len = LenDist::Uniform(16, 48);
    let w = w
        .with_arrival(ArrivalProcess::Poisson { rate: rate.max(1.0) })
        .with_seed(7);
    request::generate(&w)
}

/// Run one fault scenario; conservation (exactly-once) is asserted here
/// so *every* caller inherits gate 1.
fn run_scenario(
    scenario: &'static str,
    model: &ModelConfig,
    reqs: Vec<Request>,
    slo_ttft_s: f64,
    faults: Option<FaultSchedule>,
) -> anyhow::Result<FaultRun> {
    let offered = reqs.len();
    let mut cfg = ClusterConfig::new(
        ChipConfig::large_core(),
        FAULT_CHIPS,
        fleet_sched(),
        RouterPolicy::LeastLoaded,
    );
    cfg.slo_ttft_s = slo_ttft_s;
    let freq = cfg.freq_mhz();
    if let Some(f) = faults {
        cfg = cfg.with_faults(f);
    }
    let cm = cluster::simulate_cluster_requests(&cfg, model, reqs)?;
    anyhow::ensure!(
        cm.conserves(offered),
        "{scenario}: {} completed + {} shed != {offered} offered",
        cm.n_requests(),
        cm.shed_requests()
    );
    let agg = cm.aggregate();
    Ok(FaultRun {
        scenario,
        chips: FAULT_CHIPS,
        offered,
        completed: cm.n_requests(),
        shed: cm.shed_requests(),
        crashes: cm.faults.crashes,
        restarts: cm.faults.restarts,
        degradations: cm.faults.degradations,
        recovered: cm.faults.recovered,
        retries: cm.faults.retries,
        recovery_shed: cm.faults.recovery_shed,
        tokens_recomputed: cm.faults.tokens_recomputed,
        tokens_restored: cm.faults.tokens_restored,
        mean_detect_s: cm.faults.mean_detect_s(freq),
        slo_ttft_s,
        goodput_tok_s: agg.goodput_tokens_per_s(slo_ttft_s, overload_study::SLO_TBT_S),
        tok_s: agg.tokens_per_s(),
    })
}

/// The four-scenario comparison the bench's `"fault"` section reports.
pub fn bench_rows(opts: &Opts) -> anyhow::Result<Vec<FaultRun>> {
    let model = ModelConfig::qwen3_4b();
    let n = opts.pick(96, 24);
    let per_chip = overload_study::sustainable_rate(&model, opts.pick(24, 8))?;
    let slo_ttft_s = overload_study::SLO_SERVICE_PERIODS / per_chip;
    // Half the fleet's aggregate capacity: headroom for recovery, but
    // enough load that a dead chip's share is visible.
    let rate = per_chip * FAULT_CHIPS as f64 * 0.5;
    let reqs = fault_trace(n, rate);
    let horizon = n as f64 / rate.max(1.0);
    // Crash chip 0 a fraction of a service period after the first
    // arrival: least-loaded routing breaks the initial tie toward chip 0,
    // so the crash is guaranteed to strand in-flight work (the recovery
    // path demonstrably fires on every trace).
    let crash_at = reqs.first().map_or(0.0, |r| r.arrival_s) + 0.2 / per_chip;
    let crash = |recovery: RecoveryPolicy| {
        FaultSchedule::new(vec![FaultEvent {
            at_s: crash_at,
            chip: 0,
            kind: FaultKind::ChipCrash {
                restart_after_s: None,
            },
        }])
        .with_retries(6, 0.002)
        .with_recovery(recovery)
    };
    let degrade = FaultSchedule::new(vec![
        FaultEvent {
            at_s: 0.2 * horizon,
            chip: 1,
            kind: FaultKind::LinkDegrade {
                factor: 0.4,
                duration_s: 0.4 * horizon,
            },
        },
        FaultEvent {
            at_s: 0.2 * horizon,
            chip: 2,
            kind: FaultKind::HbmThrottle {
                factor: 0.5,
                duration_s: 0.4 * horizon,
            },
        },
    ]);
    Ok(vec![
        run_scenario("none", &model, reqs.clone(), slo_ttft_s, None)?,
        run_scenario(
            "crash_recover",
            &model,
            reqs.clone(),
            slo_ttft_s,
            Some(crash(RecoveryPolicy::Recover)),
        )?,
        run_scenario(
            "crash_resubmit",
            &model,
            reqs.clone(),
            slo_ttft_s,
            Some(crash(RecoveryPolicy::Resubmit {
                client_timeout_s: slo_ttft_s,
            })),
        )?,
        run_scenario("degrade", &model, reqs, slo_ttft_s, Some(degrade))?,
    ])
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let runs = bench_rows(opts)?;

    let mut t = Table::new(
        "fault_study — steady trace at 0.5x fleet capacity under injected faults \
         (Qwen3-4B, 4 large-core chips)",
        &[
            "scenario",
            "offered",
            "completed",
            "shed",
            "crash/restart/degrade",
            "recovered",
            "retries",
            "tokens recomputed/restored",
            "detect (ms)",
            "goodput tok/s (SLO)",
            "tok/s",
        ],
    );
    for r in &runs {
        t.row(&[
            r.scenario.to_string(),
            r.offered.to_string(),
            r.completed.to_string(),
            r.shed.to_string(),
            format!("{}/{}/{}", r.crashes, r.restarts, r.degradations),
            r.recovered.to_string(),
            r.retries.to_string(),
            format!("{}/{}", r.tokens_recomputed, r.tokens_restored),
            f3(r.mean_detect_s * 1e3),
            f3(r.goodput_tok_s),
            f3(r.tok_s),
        ]);
    }

    let by = |s: &str| runs.iter().find(|r| r.scenario == s).unwrap();
    let (none, rec, res) = (by("none"), by("crash_recover"), by("crash_resubmit"));
    let floor = 1.0 - 2.0 / FAULT_CHIPS as f64 - 0.35;
    println!(
        "fault_study: goodput under SLO (TTFT<{:.4}s) — none {:.1} tok/s, \
         crash+recover {:.1} ({:.0}% of healthy, bound {:.0}%), crash+resubmit {:.1}; \
         detection {:.1} ms, {} recovered / {} retries / {} recovery-shed",
        none.slo_ttft_s,
        none.goodput_tok_s,
        rec.goodput_tok_s,
        if none.goodput_tok_s > 0.0 {
            rec.goodput_tok_s / none.goodput_tok_s * 100.0
        } else {
            0.0
        },
        floor.max(0.0) * 100.0,
        res.goodput_tok_s,
        rec.mean_detect_s * 1e3,
        rec.recovered,
        rec.retries,
        rec.recovery_shed
    );

    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_trace_is_deterministic_and_sorted() {
        let reqs = fault_trace(32, 40.0);
        assert_eq!(reqs.len(), 32);
        assert_eq!(reqs, fault_trace(32, 40.0));
        assert!(reqs.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }

    #[test]
    fn gates_hold_at_fast_scale() {
        // The three bench_check gates, asserted at the same scale CI
        // smoke-runs: exactly-once (inside run_scenario), recovery
        // strictly beating client-timeout resubmission, and the bounded
        // single-chip-crash degradation.
        let runs = bench_rows(&Opts::fast()).unwrap();
        assert_eq!(runs.len(), 4);
        let by = |s: &str| runs.iter().find(|r| r.scenario == s).unwrap();
        let (none, rec, res, deg) = (
            by("none"),
            by("crash_recover"),
            by("crash_resubmit"),
            by("degrade"),
        );
        assert_eq!(none.crashes + none.degradations, 0);
        assert_eq!(none.completed, none.offered);
        for r in [rec, res] {
            assert_eq!(r.crashes, 1, "{}", r.scenario);
        }
        assert!(rec.recovered > 0, "the early crash must strand work");
        assert!(rec.tokens_recomputed > 0);
        assert!(
            rec.mean_detect_s > 0.0
                && rec.mean_detect_s <= crate::serving::faults::DEFAULT_HEARTBEAT_S + 1e-9,
            "detection {} outside one heartbeat",
            rec.mean_detect_s
        );
        assert!(
            rec.goodput_tok_s > res.goodput_tok_s,
            "recover {} !> resubmit {}",
            rec.goodput_tok_s,
            res.goodput_tok_s
        );
        let floor = (1.0 - 2.0 / FAULT_CHIPS as f64 - 0.35).max(0.0);
        assert!(
            rec.goodput_tok_s >= none.goodput_tok_s * floor,
            "crash goodput {} below {} x healthy {}",
            rec.goodput_tok_s,
            floor,
            none.goodput_tok_s
        );
        assert_eq!(deg.degradations, 2);
        assert_eq!(deg.crashes, 0);
        assert!(deg.goodput_tok_s > 0.0);
    }
}
