//! LLM model configuration: the Qwen3 family evaluated in the paper
//! (dense 1.7B–32B plus the 30B-A3B MoE), with derived sizes (parameter
//! bytes, KV bytes/token, per-layer GEMM shapes).

/// Mixture-of-experts parameters (Qwen3-30B-A3B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MoeConfig {
    /// Total routed experts per layer.
    pub n_experts: usize,
    /// Experts activated per token.
    pub top_k: usize,
    /// Per-expert FFN intermediate size.
    pub expert_intermediate: usize,
}

/// Transformer model architecture description.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Dense FFN intermediate size (ignored for pure-MoE layers).
    pub intermediate: usize,
    pub vocab: usize,
    pub moe: Option<MoeConfig>,
    /// Weight/activation element size in bytes (bf16 = 2).
    pub dtype_bytes: u64,
    /// Maximum context length used for KV buffer sizing.
    pub max_context: usize,
}

impl ModelConfig {
    // ---- Qwen3 presets (§5.1 "Model selection") -------------------------

    pub fn qwen3_1_7b() -> Self {
        Self::dense("qwen3_1.7b", 28, 2048, 16, 8, 6144)
    }
    pub fn qwen3_4b() -> Self {
        Self::dense("qwen3_4b", 36, 2560, 32, 8, 9728)
    }
    pub fn qwen3_8b() -> Self {
        Self::dense("qwen3_8b", 36, 4096, 32, 8, 12288)
    }
    pub fn qwen3_14b() -> Self {
        Self::dense("qwen3_14b", 40, 5120, 40, 8, 17408)
    }
    pub fn qwen3_32b() -> Self {
        Self::dense("qwen3_32b", 64, 5120, 64, 8, 25600)
    }
    /// Qwen3-30B-A3B: 128 experts, 8 active, 768 expert intermediate.
    pub fn qwen3_30b_a3b() -> Self {
        let mut m = Self::dense("qwen3_30b_a3b", 48, 2048, 32, 4, 6144);
        m.moe = Some(MoeConfig {
            n_experts: 128,
            top_k: 8,
            expert_intermediate: 768,
        });
        m
    }

    /// All paper models, for sweep loops.
    pub fn paper_models() -> Vec<ModelConfig> {
        vec![
            Self::qwen3_1_7b(),
            Self::qwen3_4b(),
            Self::qwen3_8b(),
            Self::qwen3_14b(),
            Self::qwen3_32b(),
            Self::qwen3_30b_a3b(),
        ]
    }

    /// Look up a preset by name (CLI `--model`).
    pub fn by_name(name: &str) -> anyhow::Result<ModelConfig> {
        let norm = name.to_ascii_lowercase().replace(['-', '.'], "_");
        Self::paper_models()
            .into_iter()
            .find(|m| m.name.replace(['-', '.'], "_") == norm)
            .ok_or_else(|| anyhow::anyhow!("unknown model {name:?}"))
    }

    fn dense(
        name: &str,
        layers: usize,
        hidden: usize,
        heads: usize,
        kv_heads: usize,
        intermediate: usize,
    ) -> Self {
        ModelConfig {
            name: name.into(),
            layers,
            hidden,
            heads,
            kv_heads,
            head_dim: 128,
            intermediate,
            vocab: 151_936,
            moe: None,
            dtype_bytes: 2,
            max_context: 32 * 1024,
        }
    }

    // ---- Derived sizes ---------------------------------------------------

    /// Attention projection dims.
    pub fn q_dim(&self) -> usize {
        self.heads * self.head_dim
    }
    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    /// Parameter count (weights only, embeddings tied).
    pub fn n_params(&self) -> u64 {
        let h = self.hidden as u64;
        let attn = h * self.q_dim() as u64 // Wq
            + 2 * h * self.kv_dim() as u64 // Wk, Wv
            + self.q_dim() as u64 * h; // Wo
        let ffn = match self.moe {
            None => 3 * h * self.intermediate as u64, // gate, up, down
            Some(moe) => {
                let expert = 3 * h * moe.expert_intermediate as u64;
                let router = h * moe.n_experts as u64;
                expert * moe.n_experts as u64 + router
            }
        };
        let norms = 2 * h;
        let per_layer = attn + ffn + norms;
        let embed = self.vocab as u64 * h; // tied in/out
        per_layer * self.layers as u64 + embed + h // final norm
    }

    /// Total weight bytes.
    pub fn weight_bytes(&self) -> u64 {
        self.n_params() * self.dtype_bytes
    }

    /// Weight bytes for a single layer (the unit pipeline stages hold).
    pub fn layer_weight_bytes(&self) -> u64 {
        let h = self.hidden as u64;
        let attn = h * self.q_dim() as u64 + 2 * h * self.kv_dim() as u64 + self.q_dim() as u64 * h;
        let ffn = match self.moe {
            None => 3 * h * self.intermediate as u64,
            Some(moe) => {
                3 * h * moe.expert_intermediate as u64 * moe.n_experts as u64
                    + h * moe.n_experts as u64
            }
        };
        (attn + ffn + 2 * h) * self.dtype_bytes
    }

    /// KV cache bytes per token per layer (K + V).
    pub fn kv_bytes_per_token_layer(&self) -> u64 {
        2 * self.kv_dim() as u64 * self.dtype_bytes
    }

    /// KV cache bytes per token across all layers.
    pub fn kv_bytes_per_token(&self) -> u64 {
        self.kv_bytes_per_token_layer() * self.layers as u64
    }

    /// FLOPs for one forward pass over `tokens` new tokens with `context`
    /// total attended tokens (per-token-position averaged): 2·params-style
    /// estimate plus attention score/context matmuls.
    pub fn fwd_flops(&self, tokens: u64, context: u64) -> u64 {
        let h = self.hidden as u64;
        let qd = self.q_dim() as u64;
        let kvd = self.kv_dim() as u64;
        let proj = 2 * tokens * (h * qd + 2 * h * kvd + qd * h);
        let ffn = match self.moe {
            None => 2 * tokens * 3 * h * self.intermediate as u64,
            Some(moe) => {
                2 * tokens * 3 * h * moe.expert_intermediate as u64 * moe.top_k as u64
                    + 2 * tokens * h * moe.n_experts as u64
            }
        };
        // QK^T and PV: per head, tokens × context × head_dim each.
        let attn = 2 * 2 * tokens * context * (self.heads * self.head_dim) as u64;
        (proj + ffn + attn) * self.layers as u64 + 2 * tokens * h * self.vocab as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_near_nominal() {
        // Within ~20% of the marketing size (nominal sizes are approximate
        // and tokenizer/config details differ slightly).
        let cases = [
            (ModelConfig::qwen3_1_7b(), 1.7e9),
            (ModelConfig::qwen3_4b(), 4.0e9),
            (ModelConfig::qwen3_8b(), 8.0e9),
            (ModelConfig::qwen3_14b(), 14.0e9),
            (ModelConfig::qwen3_32b(), 32.0e9),
            (ModelConfig::qwen3_30b_a3b(), 30.0e9),
        ];
        for (m, nominal) in cases {
            let p = m.n_params() as f64;
            let ratio = p / nominal;
            assert!(
                (0.75..1.35).contains(&ratio),
                "{}: {:.2}B vs nominal {:.1}B (ratio {ratio:.2})",
                m.name,
                p / 1e9,
                nominal / 1e9
            );
        }
    }

    #[test]
    fn kv_bytes_per_token() {
        let m = ModelConfig::qwen3_4b();
        // 8 kv heads × 128 dim × 2 (K+V) × 2 bytes × 36 layers = 147456.
        assert_eq!(m.kv_bytes_per_token(), 8 * 128 * 2 * 2 * 36);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(ModelConfig::by_name("qwen3_4b").unwrap().hidden, 2560);
        assert_eq!(ModelConfig::by_name("Qwen3-8B").unwrap().hidden, 4096);
        assert!(ModelConfig::by_name("llama").is_err());
    }

    #[test]
    fn moe_params_dominated_by_experts() {
        let m = ModelConfig::qwen3_30b_a3b();
        let moe = m.moe.unwrap();
        assert_eq!(moe.n_experts, 128);
        assert_eq!(moe.top_k, 8);
        // Active params per token should be a small fraction of total.
        let active_flops = m.fwd_flops(1, 1) as f64;
        let dense32 = ModelConfig::qwen3_32b().fwd_flops(1, 1) as f64;
        assert!(active_flops < dense32 / 3.0);
    }

    #[test]
    fn prefill_flops_scale_linearly_in_tokens() {
        let m = ModelConfig::qwen3_4b();
        let f1 = m.fwd_flops(128, 128);
        let f2 = m.fwd_flops(256, 256);
        let ratio = f2 as f64 / f1 as f64;
        assert!(ratio > 1.9 && ratio < 2.4, "ratio={ratio}");
    }
}
