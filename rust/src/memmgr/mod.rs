//! Hierarchical memory management (§4.2): multi-grained KV cache across
//! SRAM and HBM, the SRAM budget planner, and prefix-sharing block reuse.
//!
//! The paper's scheme (Fig. 5):
//!
//! - **SRAM** is scarce, so the KV cache living there is managed
//!   *fine-grained*, at **block** granularity — a request's KV tensor is an
//!   ordered table of (possibly non-contiguous) block IDs, and a free-block
//!   list recycles blocks as requests retire ([`blocks`]). Blocks are
//!   ref-counted so identical prompt prefixes are stored once and shared.
//! - **HBM** is plentiful and strongly prefers sequential access, so
//!   spilled KV is managed *coarse-grained*: one whole max-length buffer
//!   per request, organised as a **ring buffer** ([`ring`]).
//! - [`kv`] combines both: appends go to SRAM while blocks remain, then
//!   spill to the request's HBM buffer; per-request SRAM/HBM residency is
//!   what the attention operator uses to charge HBM streaming time.
//! - [`prefix`] is the deterministic radix/trie index over token-block
//!   hashes behind prefix caching: admission matches the longest cached
//!   prefix, shares its ref-counted blocks (copy-on-write on divergence),
//!   and ref-count-aware LRU eviction keeps hot shared prefixes resident
//!   under pressure. With the **HBM tier** enabled
//!   ([`KvCache::enable_hbm_tier`]) eviction becomes demotion: cold
//!   prefixes move to a bounded HBM region and re-promote on a hit at
//!   charged HBM→SRAM transfer cost instead of being recomputed.
//! - [`planner`] computes the SRAM budget split between activations,
//!   communication staging, temporaries, KV blocks, and resident weights
//!   (in that priority order — §4.2 "weight and activation management").

pub mod blocks;
pub mod kv;
pub mod planner;
pub mod prefix;
pub mod ring;

pub use blocks::BlockAllocator;
pub use kv::{KvCache, KvResidency, KvStats};
pub use planner::SramPlan;
pub use prefix::{BlockKey, PrefixIndex, Tier, TierMatch};
pub use ring::RingBuffer;

/// Tokens per fine-grained SRAM KV block — the prefix-cache hash
/// granularity shared by every worker (hashes are only comparable when
/// every cache blocks tokens identically).
pub const KV_BLOCK_TOKENS: u64 = 16;
