//! Trace replay: load serving request traces from JSONL files in the
//! Mooncake open-trace format (`{"timestamp": ms, "input_length": n,
//! "output_length": m, ...}` per line) so real traces drop in wherever the
//! synthetic generators are used (§5.1 references the Mooncake and
//! ShareGPT traces; the synthetic workloads match their marginals, and
//! this loader replays the real files when available).
//!
//! The parser handles the flat JSON objects these traces consist of
//! without a JSON dependency: top-level numeric fields are extracted by
//! key; nested arrays/objects (e.g. Mooncake's `hash_ids`) are skipped.

use crate::serving::request::{Priority, Request};
use anyhow::{Context, Result};
use std::path::Path;

/// Extract a top-level numeric field from one flat JSON object line.
fn field_f64(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\"");
    let at = line.find(&pat)?;
    let rest = &line[at + pat.len()..];
    let colon = rest.find(':')?;
    let rest = rest[colon + 1..].trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == 'e' || c == 'E' || c == '+'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Extract a top-level string field from one flat JSON object line.
fn field_str<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\"");
    let at = line.find(&pat)?;
    let rest = &line[at + pat.len()..];
    let colon = rest.find(':')?;
    let rest = rest[colon + 1..].trim_start().strip_prefix('"')?;
    Some(&rest[..rest.find('"')?])
}

/// Parse one trace line; returns `None` for blank/comment lines.
fn parse_line(line: &str, id: u64) -> Result<Option<Request>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    // Mooncake: timestamp (ms) / input_length / output_length.
    // ShareGPT-style exports: arrival_time (s) / prompt_len / completion_len.
    let ts_ms = field_f64(line, "timestamp");
    let ts_s = field_f64(line, "arrival_time");
    let input = field_f64(line, "input_length")
        .or_else(|| field_f64(line, "prompt_len"))
        .with_context(|| format!("trace line {id}: no input_length/prompt_len"))?;
    let output = field_f64(line, "output_length")
        .or_else(|| field_f64(line, "completion_len"))
        .with_context(|| format!("trace line {id}: no output_length/completion_len"))?;
    let arrival_s = ts_s.or(ts_ms.map(|t| t / 1e3)).unwrap_or(0.0);
    // Optional shared-prefix annotations (our JSONL extension; Mooncake's
    // `hash_ids` arrays are block hashes we approximate with scope ids).
    let prefix = crate::serving::request::Prefix {
        group_id: field_f64(line, "prefix_group").unwrap_or(0.0) as u64,
        group_tokens: field_f64(line, "prefix_len").unwrap_or(0.0) as u32,
        conv_id: field_f64(line, "conv_id").unwrap_or(0.0) as u64,
        conv_tokens: field_f64(line, "conv_len").unwrap_or(0.0) as u32,
    };
    // Optional scheduling class (our JSONL extension): `"priority":
    // "low"|"normal"|"high"`; absent means normal.
    let priority = match field_str(line, "priority") {
        Some(s) => Priority::parse(s).with_context(|| format!("trace line {id}"))?,
        None => Priority::default(),
    };
    Ok(Some(Request {
        id,
        arrival_s,
        input_len: (input as usize).max(1),
        output_len: (output as usize).max(1),
        prefix,
        priority,
    }))
}

/// Parse a whole JSONL trace (arrivals re-based to start at 0 and sorted).
pub fn parse_jsonl(text: &str) -> Result<Vec<Request>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if let Some(r) = parse_line(line, i as u64)? {
            out.push(r);
        }
    }
    anyhow::ensure!(!out.is_empty(), "trace contains no requests");
    out.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    let t0 = out[0].arrival_s;
    for r in &mut out {
        r.arrival_s -= t0;
    }
    Ok(out)
}

/// Load a JSONL trace file, optionally truncated to `limit` requests.
pub fn load_jsonl(path: impl AsRef<Path>, limit: Option<usize>) -> Result<Vec<Request>> {
    let path = path.as_ref();
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path:?}"))?;
    let mut reqs = parse_jsonl(&text)?;
    if let Some(n) = limit {
        reqs.truncate(n);
    }
    Ok(reqs)
}

/// Serialize requests back to Mooncake-format JSONL (round-trip support;
/// also used to export synthetic traces for other tools).
pub fn to_jsonl(reqs: &[Request]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for r in reqs {
        let p = &r.prefix;
        let prefix_fields = if p.is_none() {
            String::new()
        } else {
            format!(
                ", \"prefix_group\": {}, \"prefix_len\": {}, \"conv_id\": {}, \"conv_len\": {}",
                p.group_id, p.group_tokens, p.conv_id, p.conv_tokens
            )
        };
        let priority_field = if r.priority == Priority::default() {
            String::new()
        } else {
            format!(", \"priority\": \"{}\"", r.priority.name())
        };
        let _ = writeln!(
            out,
            "{{\"timestamp\": {}, \"input_length\": {}, \"output_length\": {}{prefix_fields}{priority_field}, \"hash_ids\": []}}",
            (r.arrival_s * 1e3).round() as u64,
            r.input_len,
            r.output_len
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const MOONCAKE: &str = r#"
{"timestamp": 5000, "input_length": 1200, "output_length": 64, "hash_ids": [1, 2, 3]}
{"timestamp": 1000, "input_length": 300, "output_length": 128, "hash_ids": []}
{"timestamp": 1500, "input_length": 800, "output_length": 32, "hash_ids": [7]}
"#;

    #[test]
    fn parses_mooncake_lines_sorted_and_rebased() {
        let reqs = parse_jsonl(MOONCAKE).unwrap();
        assert_eq!(reqs.len(), 3);
        assert_eq!(reqs[0].arrival_s, 0.0); // rebased to first arrival (1.0s)
        assert_eq!(reqs[0].input_len, 300);
        assert!((reqs[1].arrival_s - 0.5).abs() < 1e-9);
        assert!((reqs[2].arrival_s - 4.0).abs() < 1e-9);
        assert_eq!(reqs[2].output_len, 64);
    }

    #[test]
    fn parses_sharegpt_style_fields() {
        let text = r#"{"arrival_time": 2.5, "prompt_len": 42, "completion_len": 17}"#;
        let reqs = parse_jsonl(text).unwrap();
        assert_eq!(reqs[0].input_len, 42);
        assert_eq!(reqs[0].output_len, 17);
    }

    #[test]
    fn parses_and_round_trips_priority() {
        let text = r#"{"timestamp": 0, "input_length": 10, "output_length": 4, "priority": "high"}
{"timestamp": 1, "input_length": 10, "output_length": 4}"#;
        let reqs = parse_jsonl(text).unwrap();
        assert_eq!(reqs[0].priority, Priority::High);
        assert_eq!(reqs[1].priority, Priority::Normal);
        let again = parse_jsonl(&to_jsonl(&reqs)).unwrap();
        assert_eq!(again[0].priority, Priority::High);
        assert_eq!(again[1].priority, Priority::Normal);
        assert!(parse_jsonl(
            r#"{"timestamp": 0, "input_length": 1, "output_length": 1, "priority": "urgent"}"#
        )
        .is_err());
    }

    #[test]
    fn skips_blank_and_comment_lines() {
        let text = format!("# header\n\n{MOONCAKE}");
        assert_eq!(parse_jsonl(&text).unwrap().len(), 3);
    }

    #[test]
    fn missing_fields_error_with_line() {
        let err = parse_jsonl("{\"timestamp\": 1}").unwrap_err();
        assert!(format!("{err:#}").contains("line 0"));
    }

    #[test]
    fn empty_trace_rejected() {
        assert!(parse_jsonl("\n# nothing\n").is_err());
    }

    #[test]
    fn jsonl_round_trip() {
        // Ids are line numbers (they change once sorted); the payload must
        // round-trip exactly.
        let key = |r: &Request| (r.arrival_s.to_bits(), r.input_len, r.output_len);
        let reqs = parse_jsonl(MOONCAKE).unwrap();
        let again = parse_jsonl(&to_jsonl(&reqs)).unwrap();
        assert_eq!(
            reqs.iter().map(key).collect::<Vec<_>>(),
            again.iter().map(key).collect::<Vec<_>>()
        );
    }

    #[test]
    fn load_respects_limit() {
        let dir = std::env::temp_dir().join(format!("npusim_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        std::fs::write(&path, MOONCAKE).unwrap();
        assert_eq!(load_jsonl(&path, Some(2)).unwrap().len(), 2);
        assert_eq!(load_jsonl(&path, None).unwrap().len(), 3);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn replayed_trace_drives_the_fusion_engine() {
        use crate::config::{ChipConfig, LenDist, ModelConfig, WorkloadConfig};
        use crate::serving::pd_fusion::{simulate_fusion, FusionConfig};
        use crate::sim::chip::ChipSim;
        // Simulate from a trace by exporting it into a workload whose
        // generator reproduces it (fixed lengths per request are not
        // expressible; instead verify the parser feeds the same Request
        // type the engine consumes).
        let reqs = parse_jsonl(MOONCAKE).unwrap();
        assert!(reqs.iter().all(|r| r.input_len > 0 && r.output_len > 0));
        // Engine smoke with comparable shape.
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut w = WorkloadConfig::fixed_ratio(300, 16, reqs.len());
        w.input_len = LenDist::Uniform(300, 1200);
        let m = simulate_fusion(
            &mut chip,
            &ModelConfig::qwen3_4b(),
            &w,
            &FusionConfig::default(),
        )
        .unwrap();
        assert_eq!(m.n_requests(), 3);
    }
}
