//! `tier_study` — the two-tier prefix-cache ablation: SRAM-only prefix
//! caching versus the HBM demotion tier versus the full two-tier +
//! cross-pipe NoC configuration, on a shared-prefix multi-turn trace with
//! deliberate SRAM pressure (small per-core SRAM, many live
//! conversations). The study shows cross-pipe/HBM hits *replacing
//! recomputation*: the two-tier configuration must skip strictly more
//! prefill tokens than SRAM-only caching, because conversation turns that
//! round-robin onto a non-caching pipe (or whose cold prefix was evicted)
//! now import or re-promote their context instead of re-prefilling it.
//!
//! Rows feed the serving bench's `BENCH_serving.json` `"tier"` section via
//! [`bench_rows`]; `tools/bench_check` gates the skip-count invariant.
//!
//! ```sh
//! cargo run --release -p npusim -- experiment tier_study
//! ```

use crate::config::{ArrivalProcess, ChipConfig, ModelConfig, PrefixSharing, WorkloadConfig};
use crate::experiments::Opts;
use crate::serving::cluster::{self, ClusterConfig, RouterPolicy};
use crate::serving::pd_fusion::FusionConfig;
use crate::serving::request::{self, Request};
use crate::serving::scheduler::SchedulerConfig;
use crate::util::table::{f3, Table};

/// One measured tier configuration.
#[derive(Debug, Clone)]
pub struct TierRun {
    /// Configuration label (`sram-only`, `hbm-tier`, `two-tier+noc`).
    pub config: &'static str,
    /// HBM demotion tier enabled?
    pub hbm_tier: bool,
    /// Cross-pipe affinity + NoC import enabled?
    pub cross_pipe: bool,
    /// Simulated output-token throughput.
    pub tok_s: f64,
    /// Median time-to-first-token, seconds.
    pub ttft_p50_s: f64,
    /// p99 time-to-first-token, seconds.
    pub ttft_p99_s: f64,
    /// Prefix-cache hit rate over consultable admissions.
    pub hit_rate: f64,
    /// Prompt tokens whose prefill was skipped (the headline number).
    pub tokens_skipped: u64,
    /// SRAM→HBM demotions (cold prefixes preserved instead of dropped).
    pub demotions: u64,
    /// HBM→SRAM re-promotions on a hit.
    pub promotions: u64,
    /// Demoted blocks dropped when the HBM tier overflowed.
    pub dropped: u64,
    /// Single-tier evictions (cold prefixes lost; tier-off runs only).
    pub evictions: u64,
    /// Cross-pipe prefix imports over the on-chip NoC.
    pub noc_imports: u64,
}

/// The pressured shared-prefix trace: several concurrent conversations
/// with long per-conversation contexts and think time between turns, so
/// later turns find their prefix cached — if routing finds the right pipe
/// and eviction has not dropped it.
pub fn pressure_trace(opts: &Opts) -> Vec<Request> {
    let n = opts.pick(48, 16);
    let mut w = WorkloadConfig::shared_prefix(n).with_seed(41);
    w.prefix = Some(PrefixSharing {
        n_groups: (n / 2).max(1),
        shared_prefix_len: opts.pick(1024, 512),
        turns: 2,
        think_time_s: opts.pick(2.0, 1.0),
    });
    w.arrival = ArrivalProcess::Poisson {
        rate: opts.pick(4.0, 6.0),
    };
    request::generate(&w)
}

/// The pressured chip: the large-core mesh with per-core SRAM cut to
/// 16 MB, so the per-stage KV block pool is small enough that concurrent
/// conversations actually evict (or, with the tier, demote) each other.
pub fn pressure_chip() -> ChipConfig {
    ChipConfig::large_core().with_sram_mb(16)
}

/// The three configurations of the ablation, in presentation order.
pub fn tier_configs() -> [(&'static str, FusionConfig); 3] {
    let base = FusionConfig {
        prefix_cache: true,
        ..FusionConfig::default()
    };
    [
        ("sram-only", base),
        (
            "hbm-tier",
            FusionConfig {
                hbm_tier: true,
                ..base
            },
        ),
        (
            "two-tier+noc",
            FusionConfig {
                hbm_tier: true,
                cross_pipe: true,
                ..base
            },
        ),
    ]
}

/// Run one configuration over `reqs` through the streamed one-chip
/// cluster driver (cache-affinity routing needs admission-time cache
/// state, which batch init cannot see).
pub fn run_config(
    model: &ModelConfig,
    reqs: &[Request],
    name: &'static str,
    cfg: FusionConfig,
) -> anyhow::Result<TierRun> {
    let ccfg = ClusterConfig::new(
        pressure_chip(),
        1,
        SchedulerConfig::Fusion(cfg),
        RouterPolicy::RoundRobin,
    );
    let cm = cluster::simulate_cluster_requests(&ccfg, model, reqs.to_vec())?;
    let m = cm.aggregate();
    anyhow::ensure!(
        m.n_requests() == reqs.len(),
        "tier_study {name}: {} of {} requests completed",
        m.n_requests(),
        reqs.len()
    );
    let mut ttft = m.ttft_s();
    let c = m.cache;
    Ok(TierRun {
        config: name,
        hbm_tier: cfg.hbm_tier,
        cross_pipe: cfg.cross_pipe,
        tok_s: m.tokens_per_s(),
        ttft_p50_s: ttft.median(),
        ttft_p99_s: ttft.p99(),
        hit_rate: c.prefix_hit_rate(),
        tokens_skipped: c.prefill_tokens_skipped,
        demotions: c.tier_demotions,
        promotions: c.tier_promotions,
        dropped: c.tier_dropped,
        evictions: c.prefix_evictions,
        noc_imports: c.noc_prefix_imports,
    })
}

/// The three rows the serving bench embeds in `BENCH_serving.json`.
pub fn bench_rows(opts: &Opts) -> anyhow::Result<Vec<TierRun>> {
    let model = ModelConfig::qwen3_4b();
    let reqs = pressure_trace(opts);
    tier_configs()
        .into_iter()
        .map(|(name, cfg)| run_config(&model, &reqs, name, cfg))
        .collect()
}

/// Tokens-skipped lookup by configuration label.
pub fn tokens_skipped(runs: &[TierRun], config: &str) -> Option<u64> {
    runs.iter()
        .find(|r| r.config == config)
        .map(|r| r.tokens_skipped)
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let runs = bench_rows(opts)?;
    let mut t = Table::new(
        "tier_study — two-tier prefix cache on the pressured shared-prefix trace (Qwen3-4B, 16 MB SRAM/core)",
        &[
            "config",
            "tok/s",
            "TTFT p50 (s)",
            "TTFT p99 (s)",
            "hit rate (%)",
            "tokens skipped",
            "demote/promote/drop",
            "evictions",
            "NoC imports",
        ],
    );
    for r in &runs {
        t.row(&[
            r.config.to_string(),
            f3(r.tok_s),
            f3(r.ttft_p50_s),
            f3(r.ttft_p99_s),
            f3(r.hit_rate * 100.0),
            r.tokens_skipped.to_string(),
            format!("{}/{}/{}", r.demotions, r.promotions, r.dropped),
            r.evictions.to_string(),
            r.noc_imports.to_string(),
        ]);
    }
    let sram_only = tokens_skipped(&runs, "sram-only").unwrap_or(0);
    let two_tier = tokens_skipped(&runs, "two-tier+noc").unwrap_or(0);
    println!(
        "tier_study: prefill tokens skipped — sram-only {sram_only} vs two-tier+noc {two_tier} \
         ({:+.1}%)",
        if sram_only > 0 {
            (two_tier as f64 / sram_only as f64 - 1.0) * 100.0
        } else {
            0.0
        }
    );
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pressure_trace_is_deterministic_and_shareable() {
        let opts = Opts::fast();
        let reqs = pressure_trace(&opts);
        assert_eq!(reqs.len(), 16);
        assert!(request::shared_token_fraction(&reqs) >= 0.4);
        assert_eq!(reqs, pressure_trace(&opts));
    }

    #[test]
    fn two_tier_skips_strictly_more_prefill_than_sram_only() {
        // The acceptance property at fast scale: cross-pipe/HBM hits must
        // replace recomputation that SRAM-only caching performs.
        let runs = bench_rows(&Opts::fast()).unwrap();
        assert_eq!(runs.len(), 3);
        let sram_only = tokens_skipped(&runs, "sram-only").unwrap();
        let two_tier = tokens_skipped(&runs, "two-tier+noc").unwrap();
        assert!(
            two_tier > sram_only,
            "two-tier skipped {two_tier} !> sram-only {sram_only}"
        );
        // The HBM tier alone must never skip less than SRAM-only (it only
        // preserves blocks eviction would have dropped).
        let hbm = tokens_skipped(&runs, "hbm-tier").unwrap();
        assert!(hbm >= sram_only, "hbm-tier skipped {hbm} < {sram_only}");
        // Tier-off runs must report zero tier activity.
        let base = runs.iter().find(|r| r.config == "sram-only").unwrap();
        assert_eq!((base.demotions, base.promotions, base.noc_imports), (0, 0, 0));
    }

    // Determinism of the tier runs is pinned by the golden vector in
    // `rust/tests/golden_metrics.rs` (two_tier_cross_pipe_runs_are_
    // deterministic) — not duplicated here to keep the pressured cluster
    // simulation from running twice more in CI.
}
