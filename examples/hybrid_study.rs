//! Hybrid study: when does the adaptive hybrid scheduler beat the static
//! PD-fusion / PD-disaggregation choice? Runs the three schedulers over a
//! bursty long-prompt (Mooncake-like) and a steady conversational
//! (ShareGPT-like) trace through the unified `Scheduler` trait.
//!
//! Run: `cargo run --release --example hybrid_study`

use npusim::config::{ChipConfig, ModelConfig, WorkloadConfig};
use npusim::serving::pd_disagg::DisaggConfig;
use npusim::serving::pd_fusion::FusionConfig;
use npusim::serving::request;
use npusim::serving::scheduler::{self, HybridConfig, HybridScheduler, SchedulerConfig};
use npusim::sim::chip::ChipSim;
use npusim::util::table::{f3, Table};

fn main() -> anyhow::Result<()> {
    let model = ModelConfig::qwen3_4b();
    let n = 12;
    let traces = [
        ("bursty (mooncake-like)", request::generate(&WorkloadConfig::mooncake_like(n))),
        ("poisson (sharegpt-like)", request::generate(&WorkloadConfig::sharegpt_like(n))),
    ];

    let mut t = Table::new(
        "adaptive hybrid vs static schedulers (Qwen3-4B, 64 cores)",
        &["workload", "system", "tok/s", "TTFT mean (s)", "TBT mean (ms)"],
    );
    for (label, reqs) in &traces {
        for sys in [
            SchedulerConfig::Fusion(FusionConfig::default()),
            SchedulerConfig::Disagg(DisaggConfig::p42_d21()),
            SchedulerConfig::Hybrid(HybridConfig::default()),
        ] {
            let mut chip = ChipSim::new(ChipConfig::large_core());
            let m = match sys {
                SchedulerConfig::Hybrid(c) => {
                    let mut sched = HybridScheduler::new(c);
                    let m =
                        scheduler::simulate_requests(&mut chip, &model, reqs.clone(), &mut sched)?;
                    println!(
                        "[{label}] hybrid: {} re-partition(s), {} dedicated prefill pipe(s) at exit",
                        sched.repartitions(),
                        sched.n_prefill_pipes()
                    );
                    m
                }
                other => {
                    let mut sched = other.build();
                    scheduler::simulate_requests(&mut chip, &model, reqs.clone(), sched.as_mut())?
                }
            };
            t.row(&[
                label.to_string(),
                sys.name().to_string(),
                f3(m.tokens_per_s()),
                f3(m.ttft_s().mean()),
                f3(m.tbt_s().mean() * 1e3),
            ]);
        }
    }
    t.print();
    println!(
        "\nguidance: the hybrid starts fully fused and dedicates prefill pipelines\n\
         only under sustained prefill backlog, so it tracks fusion on steady\n\
         decode-heavy traffic and moves toward disaggregation under bursts."
    );
    Ok(())
}
