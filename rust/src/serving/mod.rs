//! The LLM serving framework (§4): streaming requests, iteration-level
//! scheduling, PD fusion and PD disaggregation, and serving metrics.
//!
//! - [`request`]: synthetic trace generation (ShareGPT / Mooncake-like
//!   marginals, Poisson / bursty arrivals).
//! - [`layout`]: carving the chip mesh into pipeline stages of TP groups.
//! - [`worker`]: one placed TP group with its SRAM plan and KV cache.
//! - [`scheduler`]: the unified [`scheduler::Scheduler`] trait, the shared
//!   `simulate` driver, and the three policies behind it — fusion, disagg,
//!   and the adaptive hybrid (`scheduler::hybrid`).
//! - [`pd_fusion`]: chunked-prefill budget scheduler co-locating prefill
//!   and decode on every pipeline (§4.3.2); config + wrappers.
//! - [`pd_disagg`]: dedicated prefill pipelines + decode groups with
//!   NoC KV transfer and optional heterogeneous decode cores (§4.3.1);
//!   config + wrappers.
//! - [`metrics`]: TTFT / TBT / e2e / throughput / SLO attainment.
//! - [`fleet`]: per-chip fleet description (`ChipSpec` hardware +
//!   scheduler + role, `FleetSpec`) — the cluster's construction input,
//!   including role-specialized heterogeneous fleets.
//! - [`cluster`]: the multi-chip layer — N `ChipSim`s behind a streamed
//!   admission frontend and a pluggable router (round-robin, least-loaded,
//!   prefix-hit-aware with charged cross-chip KV migration); when the
//!   fleet is role-specialized it splits each request into a prefill leg
//!   and a decode leg with a cross-chip KV handoff between them.
//! - [`faults`]: deterministic fault injection (chip crashes, link
//!   degradation, HBM throttling) and the recovery-policy knobs the
//!   cluster frontend replays them with.

pub mod cluster;
pub mod faults;
pub mod fleet;
pub mod layout;
pub mod metrics;
pub mod pd_disagg;
pub mod pd_fusion;
pub mod request;
pub mod scheduler;
pub mod trace;
pub mod worker;

pub use cluster::{
    simulate_cluster, simulate_cluster_mixed, simulate_cluster_requests, ClusterBuilder,
    ClusterConfig, ClusterMetrics, FaultStats, RecoveryRecord, Router, RouterPolicy, ShedPolicy,
    ShedScope,
};
pub use faults::{FaultEvent, FaultKind, FaultSchedule, RecoveryPolicy};
pub use fleet::{ChipSpec, FleetSpec};
pub use layout::PipelineLayout;
pub use metrics::{CacheStats, Metrics, RequestRecord};
pub use pd_disagg::{simulate_disagg, DisaggConfig};
pub use pd_fusion::{simulate_fusion, FusionConfig};
pub use request::{Prefix, Priority, Request};
pub use scheduler::{HybridConfig, HybridScheduler, Incomplete, Scheduler, SchedulerConfig};
pub use trace::{load_jsonl, parse_jsonl};
pub use worker::StageWorker;
