//! Fig. 8 — hardware configuration space exploration: single-request
//! latency of the Qwen3 family while sweeping per-core SRAM size, systolic
//! array dimension and HBM bandwidth (64 cores, TP=4, prefill:decode 5:1).

use crate::config::{ChipConfig, ModelConfig, WorkloadConfig};
use crate::experiments::Opts;
use crate::serving::pd_fusion::{simulate_fusion, FusionConfig};
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};

/// Single-request e2e latency (s) on `chip_cfg`.
pub fn single_request_latency_s(
    chip_cfg: ChipConfig,
    model: &ModelConfig,
    input: usize,
    output: usize,
) -> f64 {
    let mut chip = ChipSim::new(chip_cfg);
    let w = WorkloadConfig::fixed_ratio(input, output, 1);
    let cfg = FusionConfig {
        tp: 4,
        stages: 4,
        ..FusionConfig::default()
    };
    let m = simulate_fusion(&mut chip, model, &w, &cfg).expect("simulation failed");
    m.e2e_s().max()
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    // Prefill:decode = 5:1 (paper's setting).
    let (input, output) = opts.pick((500, 100), (80, 16));
    let srams = opts.pick(vec![8u64, 32, 128], vec![8, 32]);
    let sas = opts.pick(vec![32u64, 64, 128], vec![32, 128]);
    let hbms = opts.pick(vec![30.0f64, 120.0, 480.0], vec![30.0, 480.0]);
    let models: Vec<ModelConfig> = if opts.fast {
        vec![ModelConfig::qwen3_4b()]
    } else {
        vec![
            ModelConfig::qwen3_4b(),
            ModelConfig::qwen3_8b(),
            ModelConfig::qwen3_14b(),
            ModelConfig::qwen3_32b(),
        ]
    };

    let mut tables = Vec::new();
    for model in &models {
        let mut t = Table::new(
            &format!(
                "Fig 8 — {} single-request latency (s), 64 cores TP=4, {input}:{output}",
                model.name
            ),
            &["config", "hbm30", "hbm120", "hbm480"],
        );
        for &sram in &srams {
            for &sa in &sas {
                let mut row = vec![format!("S{sram}A{}", sa / 10)];
                for &hbm in &[30.0, 120.0, 480.0] {
                    if !hbms.contains(&hbm) {
                        row.push("-".into());
                        continue;
                    }
                    let chip = ChipConfig::large_core()
                        .with_sram_mb(sram)
                        .with_sa_dim(sa)
                        .with_hbm_bw(hbm);
                    row.push(f3(single_request_latency_s(chip, model, input, output)));
                }
                t.row(&row);
            }
        }
        tables.push(t);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_runs_and_sorts_sensibly() {
        let tables = run(&Opts::fast()).unwrap();
        assert_eq!(tables.len(), 1);
        assert!(tables[0].n_rows() >= 4);
    }

    #[test]
    fn bigger_systolic_array_cuts_prefill_latency() {
        let m = ModelConfig::qwen3_4b();
        let slow = single_request_latency_s(
            ChipConfig::large_core().with_sa_dim(32),
            &m,
            256,
            8,
        );
        let fast = single_request_latency_s(
            ChipConfig::large_core().with_sa_dim(128),
            &m,
            256,
            8,
        );
        assert!(fast < slow, "sa128 {fast} should beat sa32 {slow}");
    }

    #[test]
    fn hbm_bandwidth_matters_for_streamed_weights() {
        // 32B model weights cannot fit SRAM: decode is weight-streaming
        // bound, so HBM bandwidth changes latency (paper's 32B finding).
        let m = ModelConfig::qwen3_32b();
        let lo = single_request_latency_s(
            ChipConfig::large_core().with_hbm_bw(30.0),
            &m,
            64,
            8,
        );
        let hi = single_request_latency_s(
            ChipConfig::large_core().with_hbm_bw(480.0),
            &m,
            64,
            8,
        );
        assert!(hi < lo, "hbm480 {hi} should beat hbm30 {lo}");
    }
}
