//! Experiment harness: one module per table/figure of the paper's
//! evaluation (§5). Each experiment prints the same rows/series the paper
//! reports and writes a CSV under `results/`.
//!
//! | id | paper content | module |
//! |----|---------------|--------|
//! | `table2` | analytic partition costs + simulated cross-check | [`table2`] |
//! | `fig7a` | NpuSim vs reference-hardware validation | [`fig7`] |
//! | `fig7b` | detailed vs fast simulation accuracy/speed | [`fig7`] |
//! | `fig8` | hardware configuration space sweep | [`fig8`] |
//! | `fig9` | TP partition strategy vs sequence length | [`fig9`] |
//! | `fig10` | core placement strategies | [`fig10`] |
//! | `fig11` | PD core-ratio sweep | [`fig11`] |
//! | `fig12` | heterogeneous decode cores | [`fig12`] |
//! | `fig13` | PD fusion hardware sweep | [`fig13`] |
//! | `fig14` | PD disaggregation vs PD fusion | [`fig14`] |
//! | `headline` | ours vs T10 / WaferLLM / WSC-LLM | [`headline`] |
//! | `hybrid_study` | fusion vs disagg vs adaptive hybrid | [`hybrid_study`] |
//! | `bench` | prefix-cache + memoization + cluster + tier + plan bench → `BENCH_serving.json` | [`bench`] |
//! | `cluster_study` | multi-chip: chips × router × scheduler | [`cluster_study`] |
//! | `tier_study` | two-tier prefix cache: SRAM-only vs HBM tier vs +cross-pipe NoC | [`tier_study`] |
//! | `plan_study` | auto-planner: analytic plan ranking vs simulated | [`plan_study`] |
//! | `overload_study` | flash crowd at 2x load: FIFO vs shed/defer control plane | [`overload_study`] |
//! | `fault_study` | injected faults: crash recovery vs resubmit, degradation windows | [`fault_study`] |
//! | `fleet_study` | fleet-level PD disaggregation: planned heterogeneous fleet vs homogeneous fused | [`fleet_study`] |
//! | `scale_study` | two-speed simulation: parallel chip stepping + calibrated analytic fast path | [`scale_study`] |
//! | `spec_study` | speculative decoding: vanilla vs gamma × acceptance grid, token conservation | [`spec_study`] |

pub mod ablations;
pub mod bench;
pub mod cluster_study;
pub mod fault_study;
pub mod fleet_study;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod headline;
pub mod hybrid_study;
pub mod overload_study;
pub mod plan_study;
pub mod reference_hw;
pub mod scale_study;
pub mod spec_study;
pub mod table2;
pub mod tier_study;

use crate::util::table::Table;
use std::path::PathBuf;

/// Experiment options shared by every module.
#[derive(Debug, Clone)]
pub struct Opts {
    /// Shrink workloads (unit tests / smoke runs): fewer requests, shorter
    /// sequences, fewer sweep points. Figures keep their shape.
    pub fast: bool,
    /// Where CSVs are written (`None` = don't write).
    pub out_dir: Option<PathBuf>,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            fast: false,
            out_dir: Some(PathBuf::from("results")),
        }
    }
}

impl Opts {
    pub fn fast() -> Self {
        Opts {
            fast: true,
            out_dir: None,
        }
    }

    /// Pick a sweep value: full-fidelity or reduced.
    pub fn pick<T>(&self, full: T, fast: T) -> T {
        if self.fast {
            fast
        } else {
            full
        }
    }
}

/// All experiment ids, in paper order.
pub const ALL: &[&str] = &[
    "table2", "fig7a", "fig7b", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14",
    "headline", "ablations", "hybrid_study", "bench", "cluster_study", "tier_study", "plan_study",
    "overload_study", "fault_study", "fleet_study", "scale_study", "spec_study",
];

/// Run one experiment by id; returns its tables (already printed).
pub fn run(id: &str, opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let tables = match id {
        "table2" => table2::run(opts)?,
        "fig7a" => fig7::run_validation(opts)?,
        "fig7b" => fig7::run_mode_comparison(opts)?,
        "fig8" => fig8::run(opts)?,
        "fig9" => fig9::run(opts)?,
        "fig10" => fig10::run(opts)?,
        "fig11" => fig11::run(opts)?,
        "fig12" => fig12::run(opts)?,
        "fig13" => fig13::run(opts)?,
        "fig14" => fig14::run(opts)?,
        "headline" => headline::run(opts)?,
        "ablations" => ablations::run(opts)?,
        "hybrid_study" => hybrid_study::run(opts)?,
        "bench" => bench::run(opts)?,
        "cluster_study" => cluster_study::run(opts)?,
        "tier_study" => tier_study::run(opts)?,
        "plan_study" => plan_study::run(opts)?,
        "overload_study" => overload_study::run(opts)?,
        "fault_study" => fault_study::run(opts)?,
        "fleet_study" => fleet_study::run(opts)?,
        "scale_study" => scale_study::run(opts)?,
        "spec_study" => spec_study::run(opts)?,
        other => anyhow::bail!("unknown experiment {other:?} (try one of {ALL:?})"),
    };
    for t in &tables {
        t.print();
        println!();
    }
    if let Some(dir) = &opts.out_dir {
        for (i, t) in tables.iter().enumerate() {
            let name = if tables.len() == 1 {
                id.to_string()
            } else {
                format!("{id}_{i}")
            };
            t.write_csv(dir, &name)?;
        }
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_rejected() {
        assert!(run("fig99", &Opts::fast()).is_err());
    }

    #[test]
    fn table2_dispatches() {
        // Pure-analytic, instant; per-figure smoke tests live per module.
        let t = run("table2", &Opts::fast()).unwrap();
        assert!(!t.is_empty());
    }
}
