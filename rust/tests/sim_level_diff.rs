//! Differential tests for the two-speed simulation: the calibrated
//! analytic fast path (`--sim-level fast`) replayed against the
//! transaction-level reference on the same traces.
//!
//! Three layers:
//!
//! 1. **Golden pin**: with the flag unset (or explicitly `txn`) the
//!    simulation must stay byte-identical to the detailed path — the
//!    surrogate is strictly opt-in.
//! 2. **Structural invariants at the fast level**: token conservation
//!    (every completed request reports exactly its offered input/output
//!    token counts) and exactly-once completion (every offered request
//!    finishes exactly once) hold on randomized small workloads, because
//!    the fast path keeps the exact KV/scheduler bookkeeping and only
//!    substitutes iteration latency.
//! 3. **Metric agreement**: fast-level makespan / mean TTFT / mean TBT
//!    land within a loose tolerance band of the transaction-level run on
//!    every randomized workload (the tight ±10% band is gated at bench
//!    scale by `scale_study` + `tools/bench_check`; here the traces are
//!    tiny, so calibration cost amortizes over fewer replays).

use npusim::config::{ChipConfig, ModelConfig, WorkloadConfig};
use npusim::model::memo::SimLevel;
use npusim::serving::metrics::Metrics;
use npusim::serving::pd_disagg::DisaggConfig;
use npusim::serving::pd_fusion::FusionConfig;
use npusim::serving::request::{self, Request};
use npusim::serving::scheduler::{self, SchedulerConfig};
use npusim::sim::chip::ChipSim;
use std::collections::HashMap;
use std::fmt::Write as _;

/// Canonical byte rendering (mirrors `golden_metrics`): every integer
/// field of every record, sorted by id, plus the makespan.
fn summarize(m: &Metrics) -> String {
    let mut records: Vec<_> = m.records().to_vec();
    records.sort_by_key(|r| r.id);
    let mut out = String::new();
    let _ = writeln!(out, "n={} makespan={}", m.n_requests(), m.makespan());
    for r in records {
        let _ = writeln!(
            out,
            "id={} arrival={} first={} finish={} in={} out={}",
            r.id, r.arrival, r.first_token, r.finish, r.input_tokens, r.output_tokens
        );
    }
    out
}

fn run_level(sys: &SchedulerConfig, w: &WorkloadConfig) -> Metrics {
    let model = ModelConfig::qwen3_4b();
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let mut sched = sys.build();
    scheduler::simulate(&mut chip, &model, w, sched.as_mut())
        .unwrap_or_else(|e| panic!("{} failed: {e:#}", sys.name()))
}

fn fusion_at(level: SimLevel) -> SchedulerConfig {
    SchedulerConfig::Fusion(FusionConfig {
        sim_level: level,
        ..FusionConfig::default()
    })
}

fn disagg_at(level: SimLevel) -> SchedulerConfig {
    SchedulerConfig::Disagg(DisaggConfig {
        sim_level: level,
        ..DisaggConfig::p42_d21()
    })
}

/// The randomized small-workload pool the property tests replay: mixed
/// prefill/decode ratios and lengths across independent seeds.
fn workload_pool() -> Vec<WorkloadConfig> {
    let mut pool = Vec::new();
    for seed in [3u64, 17, 41] {
        pool.push(WorkloadConfig::sharegpt_like(5).with_seed(seed));
    }
    pool.push(WorkloadConfig::fixed_ratio(256, 24, 6).with_seed(7));
    pool.push(WorkloadConfig::fixed_ratio(64, 48, 5).with_seed(23));
    pool
}

/// Token conservation + exactly-once: every offered request completes
/// exactly once carrying exactly its offered token counts.
fn assert_exactly_once(tag: &str, reqs: &[Request], m: &Metrics) {
    let want: HashMap<u64, (u64, u64)> = reqs
        .iter()
        .map(|r| (r.id, (r.input_len as u64, r.output_len as u64)))
        .collect();
    assert_eq!(
        m.n_requests(),
        reqs.len(),
        "{tag}: completed != offered (lost or duplicated requests)"
    );
    let mut seen: HashMap<u64, u32> = HashMap::new();
    for rec in m.records() {
        *seen.entry(rec.id).or_insert(0) += 1;
        let (i, o) = want
            .get(&rec.id)
            .unwrap_or_else(|| panic!("{tag}: unknown request id {}", rec.id));
        assert_eq!(
            (rec.input_tokens, rec.output_tokens),
            (*i, *o),
            "{tag}: request {} token counts drifted",
            rec.id
        );
    }
    assert!(
        seen.values().all(|&c| c == 1),
        "{tag}: some request completed more than once"
    );
}

fn rel_err(x: f64, reference: f64) -> f64 {
    if reference.abs() < 1e-12 {
        return if x.abs() < 1e-12 { 0.0 } else { f64::INFINITY };
    }
    (x - reference).abs() / reference.abs()
}

/// Loose agreement band of the tiny-trace property tests; the tight ±10%
/// band is enforced at bench scale by `scale_study`.
const SMALL_TRACE_TOL: f64 = 0.30;

#[test]
fn txn_level_is_byte_identical_to_the_flag_unset_default() {
    // The golden pin: `sim_level: Txn` (and the default, which must be
    // Txn) cannot perturb a single cycle of the detailed schedule.
    assert_eq!(SimLevel::default(), SimLevel::Txn);
    for w in workload_pool() {
        let base = summarize(&run_level(
            &SchedulerConfig::Fusion(FusionConfig::default()),
            &w,
        ));
        let txn = summarize(&run_level(&fusion_at(SimLevel::Txn), &w));
        assert_eq!(base, txn, "explicit txn diverged from default on {}", w.name);
        let d_base = summarize(&run_level(
            &SchedulerConfig::Disagg(DisaggConfig::p42_d21()),
            &w,
        ));
        let d_txn = summarize(&run_level(&disagg_at(SimLevel::Txn), &w));
        assert_eq!(d_base, d_txn, "disagg txn diverged from default on {}", w.name);
    }
}

#[test]
fn fast_level_conserves_tokens_exactly_once_on_random_workloads() {
    // Layer 2: the surrogate replaces iteration *latency*, never token
    // bookkeeping — conservation must be exact, not approximate.
    for w in workload_pool() {
        let reqs = request::generate(&w);
        for (tag, sys) in [
            ("fusion/fast", fusion_at(SimLevel::Fast)),
            ("disagg/fast", disagg_at(SimLevel::Fast)),
        ] {
            let m = run_level(&sys, &w);
            assert_exactly_once(&format!("{tag} on {}", w.name), &reqs, &m);
        }
    }
}

#[test]
fn fast_level_is_deterministic() {
    // Calibration state is per-run, so two fresh fast-level runs of the
    // same trace must agree byte-for-byte.
    for w in workload_pool().into_iter().take(2) {
        let a = summarize(&run_level(&fusion_at(SimLevel::Fast), &w));
        let b = summarize(&run_level(&fusion_at(SimLevel::Fast), &w));
        assert_eq!(a, b, "fast level not deterministic on {}", w.name);
    }
}

#[test]
fn fast_level_tracks_txn_metrics_within_tolerance() {
    // Layer 3: differential metric agreement on every pooled workload.
    for w in workload_pool() {
        let txn = run_level(&fusion_at(SimLevel::Txn), &w);
        let fast = run_level(&fusion_at(SimLevel::Fast), &w);
        let pairs = [
            ("makespan", fast.makespan() as f64, txn.makespan() as f64),
            ("ttft_mean", fast.ttft_s().mean(), txn.ttft_s().mean()),
            ("tbt_mean", fast.tbt_s().mean(), txn.tbt_s().mean()),
        ];
        for (name, f, t) in pairs {
            let err = rel_err(f, t);
            assert!(
                err <= SMALL_TRACE_TOL,
                "{name} on {}: fast {f} vs txn {t} ({:.1}% > {:.0}%)",
                w.name,
                err * 100.0,
                SMALL_TRACE_TOL * 100.0
            );
        }
    }
}
