//! Multi-chip cluster study: chips × router × scheduler on a
//! shared-prefix conversational workload and a Poisson workload —
//! prefix-hit-aware routing vs least-loaded vs round-robin, with charged
//! cross-chip KV migration.
//!
//! Run: `cargo run --release --example cluster_study [-- --fast]`
//! (equivalent to `cargo run --release -p npusim -- experiment cluster_study`)

use npusim::experiments::{self, Opts};

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let opts = Opts {
        fast,
        out_dir: Some("results".into()),
    };
    experiments::run("cluster_study", &opts)?;
    println!("wrote results/cluster_study.csv");
    Ok(())
}
