//! One NPU core's simulation state: a program-order virtual clock plus the
//! core-local resources (systolic array via the compute models, SRAM port,
//! HBM channel) and cycle accounting.
//!
//! Cores execute their per-iteration operator sequence in program order;
//! cross-core interactions (collectives, P2P KV transfers) go through the
//! shared [`crate::sim::noc::Mesh`] owned by [`crate::sim::ChipSim`], which
//! synchronises the participating cores' clocks.

use crate::config::{ChipConfig, CoreConfig};
use crate::sim::compute;
use crate::sim::memory::{HbmChannel, SramPort};
use crate::sim::noc::Coord;
use crate::sim::tracer::{OpClass, Tracer};
use crate::util::units::Cycle;

/// Simulation state of a single NPU core.
#[derive(Debug)]
pub struct CoreSim {
    pub coord: Coord,
    pub cfg: CoreConfig,
    /// Program-order virtual clock.
    now: Cycle,
    pub hbm: HbmChannel,
    pub sram: SramPort,
    pub tracer: Tracer,
    chip_freq_mhz: f64,
    dtype_bytes: u64,
}

impl CoreSim {
    pub fn new(chip: &ChipConfig, coord: Coord, cfg: CoreConfig) -> Self {
        CoreSim {
            coord,
            cfg,
            now: 0,
            hbm: HbmChannel::new(chip, &cfg),
            sram: SramPort::new(chip, &cfg),
            tracer: Tracer::new(),
            chip_freq_mhz: chip.freq_mhz,
            dtype_bytes: chip.dtype_bytes,
        }
    }

    pub fn now(&self) -> Cycle {
        self.now
    }

    /// Advance this core's clock to at least `t` (synchronisation point);
    /// the gap is accounted as idle.
    pub fn advance_to(&mut self, t: Cycle) {
        if t > self.now {
            self.tracer.record(OpClass::Idle, t - self.now);
            self.now = t;
        }
    }

    /// Execute a GEMM `[m,k]×[k,n]` with weights already resident in SRAM.
    pub fn gemm(&mut self, chip: &ChipConfig, m: u64, k: u64, n: u64) -> Cycle {
        let cycles = compute::matmul_cycles(chip, &self.cfg, m, k, n);
        let class = if m <= 4 { OpClass::Gemv } else { OpClass::Gemm };
        self.tracer.record(class, cycles);
        self.now += cycles;
        self.now
    }

    /// Execute a GEMM whose weights stream from HBM, double-buffered:
    /// effective latency is `max(compute, hbm_stream)` plus the first-tile
    /// fetch (dataflow overlap — the DMA engine prefetches tile `i+1` while
    /// tile `i` computes).
    pub fn gemm_hbm_weights(
        &mut self,
        chip: &ChipConfig,
        m: u64,
        k: u64,
        n: u64,
        weight_bytes: u64,
    ) -> Cycle {
        let comp = compute::matmul_cycles(chip, &self.cfg, m, k, n);
        if weight_bytes == 0 || !self.hbm.present() {
            let class = if m <= 4 { OpClass::Gemv } else { OpClass::Gemm };
            self.tracer.record(class, comp);
            self.now += comp;
            return self.now;
        }
        // First tile fetch exposes HBM latency; the rest overlaps compute.
        let first_tile = (self.cfg.sa_dim * self.cfg.sa_dim * self.dtype_bytes).min(weight_bytes);
        let head_done = self.hbm.access(self.now, first_tile);
        let stream_done = self.hbm.access(head_done, weight_bytes - first_tile);
        let hbm_cycles = stream_done - self.now;
        let total = comp.max(hbm_cycles);
        let class = if m <= 4 { OpClass::Gemv } else { OpClass::Gemm };
        self.tracer.record(class, comp);
        if total > comp {
            self.tracer.record(OpClass::HbmWeight, total - comp);
        }
        self.now += total;
        self.now
    }

    /// Attention over the KV cache, with `kv_hbm_bytes` of the cache
    /// streamed from HBM (the spilled portion; SRAM-resident KV is covered
    /// by the compute roofline).
    pub fn attention(
        &mut self,
        chip: &ChipConfig,
        heads: u64,
        q_tokens: u64,
        kv_tokens: u64,
        head_dim: u64,
        kv_hbm_bytes: u64,
    ) -> Cycle {
        let comp = compute::attention_cycles(chip, &self.cfg, heads, q_tokens, kv_tokens, head_dim);
        let hbm_cycles = if kv_hbm_bytes > 0 && self.hbm.present() {
            self.hbm.access(self.now, kv_hbm_bytes) - self.now
        } else {
            0
        };
        let total = comp.max(hbm_cycles);
        self.tracer.record(OpClass::Attention, comp);
        if total > comp {
            self.tracer.record(OpClass::HbmKv, total - comp);
        }
        self.now += total;
        self.now
    }

    /// Vector-unit work (norms, activations, rope, residuals).
    pub fn vector(&mut self, elems: u64, passes: u64) -> Cycle {
        let cycles = compute::vector_cycles(&self.cfg, elems, passes);
        self.tracer.record(OpClass::Vector, cycles);
        self.now += cycles;
        self.now
    }

    /// Blocking HBM access (KV spill writeback, cold weight load).
    pub fn hbm_access(&mut self, bytes: u64, class: OpClass) -> Cycle {
        if bytes == 0 || !self.hbm.present() {
            return self.now;
        }
        let done = self.hbm.access(self.now, bytes);
        self.tracer.record(class, done - self.now);
        self.now = done;
        self.now
    }

    /// Core frequency (MHz) for time conversion at reporting boundaries.
    pub fn freq_mhz(&self) -> f64 {
        self.chip_freq_mhz
    }

    pub fn reset(&mut self) {
        self.now = 0;
        self.hbm.reset();
        self.sram.reset();
        self.tracer.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    fn core() -> (ChipConfig, CoreSim) {
        let chip = ChipConfig::large_core();
        let c = CoreSim::new(&chip, Coord::new(0, 0), chip.core);
        (chip, c)
    }

    #[test]
    fn gemm_advances_clock() {
        let (chip, mut c) = core();
        let t = c.gemm(&chip, 512, 512, 512);
        assert_eq!(t, 16 * 640 + 128);
        assert_eq!(c.now(), t);
        assert_eq!(c.tracer.cycles(OpClass::Gemm), t);
    }

    #[test]
    fn small_m_classified_as_gemv() {
        let (chip, mut c) = core();
        c.gemm(&chip, 1, 512, 512);
        assert!(c.tracer.cycles(OpClass::Gemv) > 0);
        assert_eq!(c.tracer.cycles(OpClass::Gemm), 0);
    }

    #[test]
    fn hbm_weights_overlap_with_compute() {
        let (chip, mut c) = core();
        // Large compute, small weights: HBM fully hidden.
        let t_small = {
            let comp = crate::sim::compute::matmul_cycles(&chip, &c.cfg, 4096, 512, 512);
            c.gemm_hbm_weights(&chip, 4096, 512, 512, 1024);
            let t = c.now();
            assert!(t <= comp + 200, "HBM not hidden: {t} vs {comp}");
            t
        };
        // Huge weights, small compute: HBM-bound.
        c.reset();
        c.gemm_hbm_weights(&chip, 1, 8192, 8192, 8192 * 8192 * 2);
        assert!(c.now() > t_small);
        assert!(c.tracer.cycles(OpClass::HbmWeight) > 0);
    }

    #[test]
    fn advance_to_records_idle() {
        let (_chip, mut c) = core();
        c.advance_to(1000);
        assert_eq!(c.now(), 1000);
        assert_eq!(c.tracer.cycles(OpClass::Idle), 1000);
        // Going backwards is a no-op.
        c.advance_to(500);
        assert_eq!(c.now(), 1000);
    }

    #[test]
    fn attention_kv_spill_adds_hbm_wait() {
        let (chip, mut c) = core();
        let t_resident = {
            c.attention(&chip, 8, 1, 2048, 128, 0);
            c.now()
        };
        c.reset();
        // 256 MB of spilled KV clearly exceeds the compute time.
        c.attention(&chip, 8, 1, 2048, 128, 256 * 1024 * 1024);
        assert!(c.now() > t_resident);
        assert!(c.tracer.cycles(OpClass::HbmKv) > 0);
    }
}
