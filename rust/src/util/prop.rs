//! Tiny property-based testing runner (proptest substitute).
//!
//! Runs a property over many randomly generated cases from a deterministic
//! seed; on failure it reports the case index and seed so the exact failing
//! input can be reproduced, and performs a simple "smallest seen" retry pass
//! for inputs that expose ordering bugs.
//!
//! Usage (`no_run`: doctest binaries cannot resolve the xla rpath in this
//! environment; the API is exercised by the in-module tests below):
//! ```no_run
//! use npusim::util::prop::check;
//! check("sum is commutative", 500, |rng| {
//!     let a = rng.range(0, 1000) as u64;
//!     let b = rng.range(0, 1000) as u64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Default number of cases for module property tests.
pub const DEFAULT_CASES: usize = 256;

/// Parse a `NPUSIM_PROP_SCALE`-style value: a positive integer multiplier,
/// anything else (unset, garbage, zero) meaning 1.
fn scale_from(var: Option<&str>) -> usize {
    var.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or(1)
}

/// `cases` multiplied by the `NPUSIM_PROP_SCALE` environment variable
/// (default 1). CI's debug job raises it to widen randomized coverage
/// without slowing local `cargo test` runs; case seeds are unchanged, so
/// a scaled run replays every unscaled case first.
pub fn scaled(cases: usize) -> usize {
    cases.saturating_mul(scale_from(std::env::var("NPUSIM_PROP_SCALE").ok().as_deref()))
}

/// Run `property` over `cases` generated cases (times the
/// `NPUSIM_PROP_SCALE` multiplier). The property receives a per-case
/// deterministic RNG; panics are caught, annotated with the case seed,
/// and re-raised.
pub fn check<F>(name: &str, cases: usize, property: F)
where
    F: Fn(&mut Rng) + std::panic::RefUnwindSafe,
{
    check_seeded(name, 0xA5A5_0000, scaled(cases), property)
}

/// Like [`check`] but with an explicit base seed (use to reproduce a
/// reported failure).
pub fn check_seeded<F>(name: &str, base_seed: u64, cases: usize, property: F)
where
    F: Fn(&mut Rng) + std::panic::RefUnwindSafe,
{
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            property(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property {name:?} failed at case {case}/{cases} \
                 (reproduce with check_seeded({name:?}, {base_seed:#x}, ..) case seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 64, |rng| {
            let a = rng.range(0, 100);
            let b = rng.range(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always fails eventually", 64, |rng| {
                assert!(rng.range(0, 10) != 3, "hit the bad value");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "message should carry the seed: {msg}");
        assert!(msg.contains("hit the bad value"));
    }

    #[test]
    fn scale_parses_defensively() {
        assert_eq!(scale_from(None), 1);
        assert_eq!(scale_from(Some("4")), 4);
        assert_eq!(scale_from(Some(" 2 ")), 2);
        assert_eq!(scale_from(Some("0")), 1, "zero would erase coverage");
        assert_eq!(scale_from(Some("garbage")), 1);
    }

    #[test]
    fn cases_are_deterministic() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let acc1 = AtomicU64::new(0);
        check("collect1", 16, |rng| {
            acc1.fetch_add(rng.next_u64() & 0xFFFF, Ordering::Relaxed);
        });
        let acc2 = AtomicU64::new(0);
        check("collect2", 16, |rng| {
            acc2.fetch_add(rng.next_u64() & 0xFFFF, Ordering::Relaxed);
        });
        assert_eq!(acc1.into_inner(), acc2.into_inner());
    }
}
