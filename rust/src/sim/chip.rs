//! Whole-chip simulation state: the mesh of [`CoreSim`]s plus the shared
//! NoC, with helpers for cross-core transfers and clock synchronisation.

use crate::config::{ChipConfig, CoreConfig};
use crate::sim::core::CoreSim;
use crate::sim::noc::{Coord, Mesh, Transfer};
use crate::sim::tracer::{OpClass, Tracer};
use crate::util::units::Cycle;

/// The simulated chip.
#[derive(Debug)]
pub struct ChipSim {
    pub cfg: ChipConfig,
    cores: Vec<CoreSim>,
    pub mesh: Mesh,
}

impl ChipSim {
    /// Build a homogeneous chip from `cfg` (decode-core overrides are
    /// applied per-core later via [`ChipSim::set_core_config`]).
    pub fn new(cfg: ChipConfig) -> Self {
        let mesh = Mesh::new(&cfg);
        let mut cores = Vec::with_capacity(cfg.n_cores());
        for r in 0..cfg.rows {
            for c in 0..cfg.cols {
                cores.push(CoreSim::new(&cfg, Coord::new(r, c), cfg.core));
            }
        }
        ChipSim { cfg, cores, mesh }
    }

    fn index(&self, c: Coord) -> usize {
        debug_assert!(c.row < self.cfg.rows && c.col < self.cfg.cols);
        c.row * self.cfg.cols + c.col
    }

    pub fn core(&self, c: Coord) -> &CoreSim {
        &self.cores[self.index(c)]
    }

    pub fn core_mut(&mut self, c: Coord) -> &mut CoreSim {
        let i = self.index(c);
        &mut self.cores[i]
    }

    pub fn cores(&self) -> &[CoreSim] {
        &self.cores
    }

    /// Replace the hardware resources of one core (heterogeneous
    /// PD-disaggregation: decode cores get different SA/HBM provisioning).
    pub fn set_core_config(&mut self, at: Coord, core_cfg: CoreConfig) {
        let i = self.index(at);
        let now = self.cores[i].now();
        let mut fresh = CoreSim::new(&self.cfg, at, core_cfg);
        fresh.advance_to(now);
        self.cores[i] = fresh;
    }

    /// Throttle every core's HBM channel to `factor` × nominal bandwidth
    /// (fault injection; `1.0` restores the nominal rate exactly). Unlike
    /// [`ChipSim::set_core_config`] this keeps clocks, tracers, and
    /// in-flight bank state intact — only future accesses slow down.
    pub fn set_hbm_throttle(&mut self, factor: f64) {
        for core in &mut self.cores {
            if core.hbm.present() {
                core.hbm.set_throttle(factor);
            }
        }
    }

    /// Point-to-point transfer: waits for the source core, moves the bytes
    /// over the NoC, and advances the destination core to the arrival time.
    pub fn send(&mut self, src: Coord, dst: Coord, bytes: u64, class: OpClass) -> Transfer {
        let depart = self.core(src).now();
        let t = self.mesh.transfer(src, dst, bytes, depart);
        let si = self.index(src);
        // Sender is busy until its tail flit leaves (channel locked).
        self.cores[si].tracer.record(class, t.finish - depart);
        self.cores[si].advance_to(t.finish);
        let di = self.index(dst);
        self.cores[di].advance_to(t.finish);
        t
    }

    /// Synchronise a group of cores to their max clock (barrier semantics
    /// at the end of a collective or pipeline handoff).
    pub fn sync(&mut self, group: &[Coord]) -> Cycle {
        let t = group
            .iter()
            .map(|&c| self.core(c).now())
            .max()
            .unwrap_or(0);
        for &c in group {
            self.core_mut(c).advance_to(t);
        }
        t
    }

    /// Max clock across all cores (end-to-end makespan).
    pub fn makespan(&self) -> Cycle {
        self.cores.iter().map(|c| c.now()).max().unwrap_or(0)
    }

    /// Aggregate tracer across all cores.
    pub fn aggregate_tracer(&self) -> Tracer {
        let mut t = Tracer::new();
        for c in &self.cores {
            t.merge(&c.tracer);
        }
        t
    }

    /// Wall-clock seconds represented by `cycles` on this chip.
    pub fn cycles_to_secs(&self, cycles: Cycle) -> f64 {
        crate::util::units::cycles_to_secs(cycles, self.cfg.freq_mhz)
    }

    pub fn reset(&mut self) {
        for c in &mut self.cores {
            c.reset();
        }
        self.mesh.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_index() {
        let chip = ChipSim::new(ChipConfig::large_core());
        assert_eq!(chip.cores().len(), 64);
        assert_eq!(chip.core(Coord::new(3, 5)).coord, Coord::new(3, 5));
    }

    #[test]
    fn send_advances_both_clocks() {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        chip.core_mut(Coord::new(0, 0)).advance_to(100);
        let t = chip.send(Coord::new(0, 0), Coord::new(0, 2), 2560, OpClass::P2P);
        assert_eq!(t.start, 100);
        assert_eq!(chip.core(Coord::new(0, 0)).now(), t.finish);
        assert_eq!(chip.core(Coord::new(0, 2)).now(), t.finish);
    }

    #[test]
    fn sync_raises_all_to_max() {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let g = [Coord::new(0, 0), Coord::new(1, 0), Coord::new(2, 0)];
        chip.core_mut(g[1]).advance_to(500);
        let t = chip.sync(&g);
        assert_eq!(t, 500);
        for c in g {
            assert_eq!(chip.core(c).now(), 500);
        }
    }

    #[test]
    fn heterogeneous_core_override() {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut decode = chip.cfg.core;
        decode.sa_dim = 32;
        decode.hbm_bw_gbps = 480.0;
        chip.set_core_config(Coord::new(7, 7), decode);
        assert_eq!(chip.core(Coord::new(7, 7)).cfg.sa_dim, 32);
        assert_eq!(chip.core(Coord::new(0, 0)).cfg.sa_dim, 128);
    }

    #[test]
    fn makespan_is_max_clock() {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        chip.core_mut(Coord::new(4, 4)).advance_to(9999);
        assert_eq!(chip.makespan(), 9999);
    }

    #[test]
    fn reset_zeroes_everything() {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        chip.core_mut(Coord::new(0, 0)).advance_to(100);
        chip.send(Coord::new(0, 0), Coord::new(0, 1), 1000, OpClass::P2P);
        chip.reset();
        assert_eq!(chip.makespan(), 0);
        assert_eq!(chip.mesh.stats().transfers, 0);
    }
}
