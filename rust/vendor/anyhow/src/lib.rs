//! Minimal in-tree substitute for the `anyhow` crate.
//!
//! The build environment is fully offline, so this vendored crate provides
//! the subset of `anyhow`'s API the repository uses: [`Error`], [`Result`],
//! the [`Context`] extension trait for `Result`/`Option`, and the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros. Error chains render like
//! anyhow's: `{e}` prints the outermost message, `{e:#}` prints the whole
//! chain joined with `: `, and `{e:?}` prints a "Caused by" listing.
//!
//! Not implemented (and not needed here): downcasting, backtraces,
//! `no_std` support.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: an outermost message plus its cause chain.
pub struct Error {
    /// `chain[0]` is the outermost (most recently attached) message.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Error {
            chain: vec![msg.to_string()],
        }
    }

    /// Attach an outer context message, pushing the current messages down
    /// the cause chain.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes the blanket `From` below (and the
// dual `IntoAnyhow` impls) coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

mod private {
    /// Sealed conversion into [`crate::Error`], implemented for both real
    /// `std::error::Error` types and `Error` itself (which does not
    /// implement `std::error::Error`, keeping the impls coherent).
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoAnyhow for E {
        fn into_anyhow(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoAnyhow for crate::Error {
        fn into_anyhow(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait attaching context to `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error (or `None`) with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoAnyhow> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_anyhow().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "Condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_modes() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing key {}", "x")).unwrap_err();
        assert_eq!(format!("{e:#}"), "missing key x");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 1);
            ensure!(x > 2, "x too small: {x}");
            if x == 9 {
                bail!("nine is right out");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(format!("{:#}", f(1).unwrap_err()).contains("Condition failed"));
        assert!(format!("{:#}", f(2).unwrap_err()).contains("x too small: 2"));
        assert!(format!("{:#}", f(9).unwrap_err()).contains("nine"));
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
