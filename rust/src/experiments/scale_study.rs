//! `scale_study` — the two-speed simulation study: one large diurnal
//! trace replayed on a 16-chip fused fleet at every simulation
//! configuration the engine offers,
//!
//! - `txn`      — the transaction-level reference: every GEMM, vector op
//!   and NoC collective priced by the detailed core model, chips stepped
//!   by the sequential event loop (`--sim-threads 1`).
//! - `txn-par8` — the same transaction-level simulation stepped by the
//!   conservative-window parallel scheduler (`--sim-threads 8`). By
//!   construction it must be **byte-identical** to `txn`; this row
//!   asserts that and reports the wall-clock effect of parallel stepping.
//! - `fast`     — the calibrated analytic surrogate
//!   ([`crate::model::memo::Surrogate`], `--sim-level fast`): the first
//!   batch of each shape class runs the detailed path to calibrate a
//!   closed-form roofline, every later batch replays the corrected
//!   analytic prediction.
//!
//! The gated acceptance properties (`BENCH_serving.json` `"scale"`
//! section, checked by `tools/bench_check`):
//!
//! 1. **The fast path is actually fast**: `speedup` (txn wall-clock over
//!    fast wall-clock) is strictly > 1 at smoke scale and ≥ 5 at full
//!    trace scale.
//! 2. **The fast path is still honest**: fast-level TTFT, TBT and
//!    goodput-under-SLO land within ±10% of the transaction-level run,
//!    and both levels conserve requests exactly
//!    (`completed + shed == offered`).
//!
//! ```sh
//! cargo run --release -p npusim -- experiment scale_study
//! ```

use crate::config::{ArrivalProcess, ChipConfig, LenDist, ModelConfig, WorkloadConfig};
use crate::experiments::{overload_study, Opts};
use crate::model::memo::SimLevel;
use crate::serving::cluster::{self, ClusterConfig, ClusterMetrics, RouterPolicy};
use crate::serving::fleet::FleetSpec;
use crate::serving::pd_fusion::FusionConfig;
use crate::serving::request::{self, Request};
use crate::serving::scheduler::SchedulerConfig;
use crate::util::table::{f3, Table};
use std::time::Instant;

/// Fleet size of the study — the ISSUE's "16+ chip fleet".
pub const SCALE_CHIPS: usize = 16;

/// Fast-vs-txn metric tolerance the bench gate arms (±10%).
pub const FAST_ERR_TOL: f64 = 0.10;

/// One simulation-level cell.
#[derive(Debug, Clone)]
pub struct ScaleRun {
    pub level: &'static str,
    pub chips: usize,
    pub sim_threads: usize,
    pub offered: usize,
    pub completed: usize,
    pub shed: u64,
    /// Simulated work retired: total tokens (input + output) across all
    /// completed requests — the event-count proxy `events_per_s` is
    /// normalized by.
    pub events: u64,
    pub wall_s: f64,
    pub events_per_s: f64,
    pub ttft_ms: f64,
    pub tbt_ms: f64,
    pub goodput_tok_s: f64,
    /// Relative error vs the `txn` reference row (0 for `txn` itself).
    pub ttft_err: f64,
    pub tbt_err: f64,
    pub goodput_err: f64,
    /// txn wall-clock / this row's wall-clock (1 for `txn` itself).
    pub speedup: f64,
}

/// The diurnal trace of the study: ShareGPT-like lengths, arrivals on a
/// raised-cosine day curve so the fleet sees both a trough and a crest.
fn scale_workload(n: usize, base_rate: f64, peak_rate: f64) -> WorkloadConfig {
    let mut w = WorkloadConfig::fixed_ratio(256, 64, n);
    w.name = "scale-diurnal".into();
    w.input_len = LenDist::Uniform(64, 512);
    w.output_len = LenDist::Uniform(16, 96);
    w.with_arrival(ArrivalProcess::Diurnal {
        base_rate,
        peak_rate,
        // Two full day-cycles over the trace: crest → trough → crest.
        period_s: (n as f64 / ((base_rate + peak_rate) * 0.5)).max(1.0) / 2.0,
    })
    .with_seed(29)
}

fn scale_sched(level: SimLevel) -> SchedulerConfig {
    SchedulerConfig::Fusion(FusionConfig {
        tp: 16,
        stages: 4,
        sim_level: level,
        ..FusionConfig::default()
    })
}

/// Run one simulation-level cell and wall-clock it. Conservation
/// (exactly-once) is asserted here so every caller inherits gate 2's
/// structural half.
fn run_level(
    level: &'static str,
    model: &ModelConfig,
    reqs: Vec<Request>,
    sim_level: SimLevel,
    sim_threads: usize,
    slo_ttft_s: f64,
) -> anyhow::Result<(ScaleRun, ClusterMetrics)> {
    let offered = reqs.len();
    let spec = FleetSpec::homogeneous(
        ChipConfig::large_core(),
        SCALE_CHIPS,
        scale_sched(sim_level),
    );
    let cfg = ClusterConfig::builder(spec)
        .router(RouterPolicy::LeastLoaded)
        .sim_threads(sim_threads)
        .build();
    let start = Instant::now();
    let cm = cluster::simulate_cluster_requests(&cfg, model, reqs)?;
    let wall_s = start.elapsed().as_secs_f64().max(1e-9);
    anyhow::ensure!(
        cm.conserves(offered),
        "{level}: {} completed + {} shed != {offered} offered",
        cm.n_requests(),
        cm.shed_requests()
    );
    let agg = cm.aggregate();
    let events: u64 = agg
        .records()
        .iter()
        .map(|r| r.input_tokens + r.output_tokens)
        .sum();
    Ok((
        ScaleRun {
            level,
            chips: SCALE_CHIPS,
            sim_threads,
            offered,
            completed: cm.n_requests(),
            shed: cm.shed_requests(),
            events,
            wall_s,
            events_per_s: events as f64 / wall_s,
            ttft_ms: agg.ttft_s().mean() * 1e3,
            tbt_ms: agg.tbt_s().mean() * 1e3,
            goodput_tok_s: agg.goodput_tokens_per_s(slo_ttft_s, overload_study::SLO_TBT_S),
            ttft_err: 0.0,
            tbt_err: 0.0,
            goodput_err: 0.0,
            speedup: 1.0,
        },
        cm,
    ))
}

fn rel_err(x: f64, reference: f64) -> f64 {
    if reference.abs() < 1e-12 {
        if x.abs() < 1e-12 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (x - reference).abs() / reference.abs()
    }
}

/// The three-row comparison the bench's `"scale"` section reports:
/// `txn` (reference), `txn-par8` (asserted byte-identical), `fast`
/// (error-scored against `txn`).
pub fn bench_rows(opts: &Opts) -> anyhow::Result<Vec<ScaleRun>> {
    let model = ModelConfig::qwen3_4b();
    let n = opts.pick(512, 48);
    let per_chip = overload_study::sustainable_rate(&model, opts.pick(24, 8))?;
    // The diurnal curve averages (base + peak) / 2 = 0.5x the fleet's
    // sustainable rate: the crest pressures it, the trough drains it.
    let fleet = per_chip * SCALE_CHIPS as f64;
    let w = scale_workload(n, fleet * 0.2, fleet * 0.8);
    let slo_ttft_s = 2.0 * overload_study::SLO_SERVICE_PERIODS / per_chip;
    let reqs = request::generate(&w);

    let (txn, txn_cm) = run_level("txn", &model, reqs.clone(), SimLevel::Txn, 1, slo_ttft_s)?;
    let (mut par, par_cm) =
        run_level("txn-par8", &model, reqs.clone(), SimLevel::Txn, 8, slo_ttft_s)?;
    // The conservative-window parallel scheduler must be bit-identical to
    // the sequential event loop — not "close", identical.
    anyhow::ensure!(
        format!("{:?}", txn_cm.aggregate().records()) == format!("{:?}", par_cm.aggregate().records()),
        "parallel stepping diverged from the sequential transaction-level schedule"
    );
    let (mut fast, _) = run_level("fast", &model, reqs, SimLevel::Fast, 1, slo_ttft_s)?;

    par.speedup = txn.wall_s / par.wall_s;
    fast.speedup = txn.wall_s / fast.wall_s;
    fast.ttft_err = rel_err(fast.ttft_ms, txn.ttft_ms);
    fast.tbt_err = rel_err(fast.tbt_ms, txn.tbt_ms);
    fast.goodput_err = rel_err(fast.goodput_tok_s, txn.goodput_tok_s);
    Ok(vec![txn, par, fast])
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let runs = bench_rows(opts)?;

    let mut t = Table::new(
        "scale_study — two-speed simulation: transaction-level vs calibrated \
         analytic surrogate (Qwen3-4B, 16 chips, diurnal trace)",
        &[
            "level",
            "threads",
            "offered",
            "completed",
            "shed",
            "events",
            "wall s",
            "events/s",
            "ttft ms",
            "tbt ms",
            "goodput tok/s",
            "speedup",
            "ttft err",
            "tbt err",
            "goodput err",
        ],
    );
    for r in &runs {
        t.row(&[
            r.level.to_string(),
            r.sim_threads.to_string(),
            r.offered.to_string(),
            r.completed.to_string(),
            r.shed.to_string(),
            r.events.to_string(),
            f3(r.wall_s),
            f3(r.events_per_s),
            f3(r.ttft_ms),
            f3(r.tbt_ms),
            f3(r.goodput_tok_s),
            f3(r.speedup),
            f3(r.ttft_err),
            f3(r.tbt_err),
            f3(r.goodput_err),
        ]);
    }

    let by = |s: &str| runs.iter().find(|r| r.level == s).unwrap();
    let (txn, fast) = (by("txn"), by("fast"));
    println!(
        "scale_study: fast path {:.1}x faster than transaction-level \
         ({:.0} vs {:.0} simulated tok per wall-s), errors ttft {:+.1}% \
         tbt {:+.1}% goodput {:+.1}% (gate ±{:.0}%)",
        fast.speedup,
        fast.events_per_s,
        txn.events_per_s,
        fast.ttft_err * 100.0,
        fast.tbt_err * 100.0,
        fast.goodput_err * 100.0,
        FAST_ERR_TOL * 100.0
    );

    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_trace_is_deterministic_and_diurnal() {
        let w = scale_workload(64, 4.0, 32.0);
        let reqs = request::generate(&w);
        assert_eq!(reqs.len(), 64);
        assert_eq!(reqs, request::generate(&w));
        assert!(reqs.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s));
        assert!(matches!(w.arrival, ArrivalProcess::Diurnal { .. }));
    }

    #[test]
    fn gates_hold_at_fast_scale() {
        // The bench_check gates, asserted at the same scale CI smoke-runs:
        // exactly-once at every level (inside run_level), parallel
        // stepping byte-identical to sequential (inside bench_rows), the
        // surrogate strictly faster than the transaction-level run, and
        // its TTFT/TBT/goodput within the ±10% tolerance band.
        let runs = bench_rows(&Opts::fast()).unwrap();
        assert_eq!(runs.len(), 3);
        let by = |s: &str| runs.iter().find(|r| r.level == s).unwrap();
        let (txn, par, fast) = (by("txn"), by("txn-par8"), by("fast"));
        for r in &runs {
            assert_eq!(r.chips, SCALE_CHIPS, "{}", r.level);
            assert_eq!(r.completed as u64 + r.shed, r.offered as u64, "{}", r.level);
            assert!(r.events > 0 && r.wall_s > 0.0, "{}", r.level);
        }
        assert_eq!(txn.sim_threads, 1);
        assert_eq!(par.sim_threads, 8);
        // Parallel stepping retires the same tokens through the same
        // schedule; identical records were already ensured in bench_rows.
        assert_eq!(par.events, txn.events);
        assert_eq!(par.ttft_ms, txn.ttft_ms);
        assert_eq!(par.tbt_ms, txn.tbt_ms);
        assert!(
            fast.speedup > 1.0,
            "surrogate must beat the detailed path: {:.2}x",
            fast.speedup
        );
        assert!(
            fast.ttft_err <= FAST_ERR_TOL
                && fast.tbt_err <= FAST_ERR_TOL
                && fast.goodput_err <= FAST_ERR_TOL,
            "fast-vs-txn error out of band: ttft {:.3} tbt {:.3} goodput {:.3}",
            fast.ttft_err,
            fast.tbt_err,
            fast.goodput_err
        );
    }
}
