//! Serving bench: prefix-sharing paged-KV study + operator-latency
//! memoization sweep, emitting `BENCH_serving.json` (wall-clock sim time,
//! simulated tokens/s, TTFT/TBT p50/p99, cache and memo hit rates).
//!
//! Run: `cargo run --release --example bench [-- --fast]`
//! (equivalent to `cargo run --release -p npusim -- experiment bench`)

use npusim::experiments::{self, Opts};

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let opts = Opts {
        fast,
        out_dir: Some("results".into()),
    };
    experiments::run("bench", &opts)?;
    println!("wrote BENCH_serving.json (and results/BENCH_serving.json)");
    Ok(())
}
